"""Regenerate the §Roofline tables inside EXPERIMENTS.md from artifacts."""
import re, sys
sys.path.insert(0, "src"); sys.path.insert(0, ".")
from benchmarks.roofline_report import markdown_table
from repro.launch.dryrun_lib import load_records

recs = load_records()
single = [r for r in recs if r['mesh'] == '16x16' and r.get('variant') == 'baseline']
multi = [r for r in recs if r['mesh'] == '2x16x16' and r.get('variant') == 'baseline']

path = "EXPERIMENTS.md"
text = open(path).read()
text = re.sub(r"<!-- ROOFLINE_SINGLE -->(.|\n)*?(?=\n### Multi-pod)",
              "<!-- ROOFLINE_SINGLE -->\n\n" + markdown_table(single) + "\n",
              text)
text = re.sub(r"<!-- ROOFLINE_MULTI -->(.|\n)*?(?=\n### Reading)",
              "<!-- ROOFLINE_MULTI -->\n\n" + markdown_table(multi) + "\n",
              text)
open(path, "w").write(text)
print("tables updated:", len(single), "single-pod rows,", len(multi), "multi-pod rows")

# --- optimized vs baseline comparison table -------------------------------
def comparison_table(recs, mesh='16x16'):
    base = {(r['arch'], r['shape']): r for r in recs
            if r['mesh'] == mesh and r.get('variant') == 'baseline'}
    opt = {(r['arch'], r['shape']): r for r in recs
           if r['mesh'] == mesh and r.get('variant') == 'optimized'}
    lines = [
        "| arch | shape | baseline max-term (s) | optimized max-term (s) | x | "
        "dominant b->o | temp/dev b->o (GB) |",
        "|---|---|---|---|---|---|---|",
    ]
    for key in sorted(base):
        b, o = base[key], opt.get(key)
        if b['status'] != 'ok' or o is None or o['status'] != 'ok':
            continue
        tb = max(b['roofline'][k] for k in
                 ('t_compute_s', 't_memory_s', 't_collective_s'))
        to = max(o['roofline'][k] for k in
                 ('t_compute_s', 't_memory_s', 't_collective_s'))
        tgb = (b['memory']['temp_bytes'] or 0) / 1e9
        tgo = (o['memory']['temp_bytes'] or 0) / 1e9
        lines.append(
            f"| {key[0]} | {key[1]} | {tb:.3e} | {to:.3e} | "
            f"**{tb/to:.1f}x** | {b['roofline']['dominant']} -> "
            f"{o['roofline']['dominant']} | {tgb:.0f} -> {tgo:.0f} |")
    return "\n".join(lines)


text = open(path).read()
both = (comparison_table(recs) + "\n\n**Multi-pod 2×16×16:**\n\n"
        + comparison_table(recs, mesh='2x16x16'))
text = re.sub(r"<!-- OPTIMIZED_TABLE -->(.|\n)*?(?=\n## §Ablations)",
              "<!-- OPTIMIZED_TABLE -->\n\n" + both + "\n",
              text)
open(path, "w").write(text)
print("optimized comparison table updated")
