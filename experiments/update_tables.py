"""Regenerate the tables inside EXPERIMENTS.md from artifacts.

Two artifact sources, each section skipped gracefully when its artifact
is missing:

* dry-run records (``experiments/artifacts/*.json`` via
  ``repro.launch.dryrun``) -> the §Roofline tables;
* benchmark CSV (``experiments/artifacts/participation.csv``, produced by
  ``PYTHONPATH=src python -m benchmarks.run --suite participation --suite
  comm > experiments/artifacts/participation.csv``) -> the §Participation
  x compression table: rounds-to-target accuracy vs participation rate,
  with the codec's modeled wire bytes per round alongside, so the
  participation and compression trade-offs land in one table.
"""
import os
import re
import sys

sys.path.insert(0, "src")
sys.path.insert(0, ".")

ART_DIR = os.path.join("experiments", "artifacts")
MD_PATH = "EXPERIMENTS.md"


def _replace_section(text, marker, end_pattern, body):
    """Swap the text between ``marker`` and ``end_pattern`` for ``body``,
    appending a fresh marker block when the file does not have one yet."""
    if marker in text:
        return re.sub(rf"{re.escape(marker)}(.|\n)*?(?={end_pattern})",
                      marker + "\n\n" + body + "\n", text)
    return text + f"\n{marker}\n\n{body}\n"


def update_roofline(text):
    from benchmarks.roofline_report import markdown_table
    from repro.launch.dryrun_lib import load_records

    recs = load_records()
    if not recs:
        print("no dry-run records; skipping roofline tables")
        return text
    single = [r for r in recs
              if r["mesh"] == "16x16" and r.get("variant") == "baseline"]
    multi = [r for r in recs
             if r["mesh"] == "2x16x16" and r.get("variant") == "baseline"]
    text = re.sub(r"<!-- ROOFLINE_SINGLE -->(.|\n)*?(?=\n### Multi-pod)",
                  "<!-- ROOFLINE_SINGLE -->\n\n" + markdown_table(single)
                  + "\n", text)
    text = re.sub(r"<!-- ROOFLINE_MULTI -->(.|\n)*?(?=\n### Reading)",
                  "<!-- ROOFLINE_MULTI -->\n\n" + markdown_table(multi)
                  + "\n", text)
    print("roofline tables updated:", len(single), "single-pod rows,",
          len(multi), "multi-pod rows")

    def comparison_table(recs, mesh="16x16"):
        base = {(r["arch"], r["shape"]): r for r in recs
                if r["mesh"] == mesh and r.get("variant") == "baseline"}
        opt = {(r["arch"], r["shape"]): r for r in recs
               if r["mesh"] == mesh and r.get("variant") == "optimized"}
        lines = [
            "| arch | shape | baseline max-term (s) | optimized max-term (s)"
            " | x | dominant b->o | temp/dev b->o (GB) |",
            "|---|---|---|---|---|---|---|",
        ]
        for key in sorted(base):
            b, o = base[key], opt.get(key)
            if b["status"] != "ok" or o is None or o["status"] != "ok":
                continue
            tb = max(b["roofline"][k] for k in
                     ("t_compute_s", "t_memory_s", "t_collective_s"))
            to = max(o["roofline"][k] for k in
                     ("t_compute_s", "t_memory_s", "t_collective_s"))
            tgb = (b["memory"]["temp_bytes"] or 0) / 1e9
            tgo = (o["memory"]["temp_bytes"] or 0) / 1e9
            lines.append(
                f"| {key[0]} | {key[1]} | {tb:.3e} | {to:.3e} | "
                f"**{tb/to:.1f}x** | {b['roofline']['dominant']} -> "
                f"{o['roofline']['dominant']} | {tgb:.0f} -> {tgo:.0f} |")
        return "\n".join(lines)

    both = (comparison_table(recs) + "\n\n**Multi-pod 2×16×16:**\n\n"
            + comparison_table(recs, mesh="2x16x16"))
    text = re.sub(r"<!-- OPTIMIZED_TABLE -->(.|\n)*?(?=\n## §Ablations)",
                  "<!-- OPTIMIZED_TABLE -->\n\n" + both + "\n", text)
    print("optimized comparison table updated")
    return text


def _parse_bench_csv(path):
    """Rows of the ``name,us_per_call,derived`` contract, derived split on
    ``;`` into a key=value dict (bare values keep their position key)."""
    rows = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line or line.startswith("name,"):
                continue
            name, us, derived = line.split(",", 2)
            fields = {}
            for part in derived.split(";"):
                k, _, v = part.partition("=")
                fields[k] = v
            rows.append((name, float(us), fields))
    return rows


def participation_table(rows):
    """participation rate / scenario x (accuracy, rounds-to-target, wire
    bytes per round) — the participation and compression trade-offs in
    one table."""
    lines = [
        "| scenario | acc | rounds-to-target | uplink bytes/round | "
        "us/round |",
        "|---|---|---|---|---|",
    ]
    for name, us, f in rows:
        parts = name.split("/")
        if len(parts) != 3 or parts[0] not in ("participation", "comm"):
            continue
        if "acc" not in f:
            continue
        # keep the middle segment: "comm:cfl/fedavg" must stay
        # distinguishable from the codec row "comm:codec/identity"
        scenario = f"{parts[0]}:{parts[1]}/{parts[2]}"
        rt_key = next((k for k in f if k.startswith("rounds_to")), None)
        rt = (f"{f[rt_key]} (acc {rt_key[len('rounds_to_'):]})"
              if rt_key else "-")
        lines.append(f"| {scenario} | {f['acc']} | {rt} | "
                     f"{f.get('bytes_per_round', '-')} | {us:.0f} |")
    if len(lines) == 2:
        return None
    return "\n".join(lines)


def update_participation(text):
    path = os.path.join(ART_DIR, "participation.csv")
    if not os.path.exists(path):
        print(f"no {path}; skipping participation x compression table "
              "(generate it with: PYTHONPATH=src python -m benchmarks.run "
              "--suite participation --suite comm > " + path + ")")
        return text
    table = participation_table(_parse_bench_csv(path))
    if table is None:
        print(f"{path} has no participation/comm rows; skipping")
        return text
    body = ("Rounds-to-target accuracy vs participation rate, with the "
            "codec's modeled uplink bytes per round (active clients × "
            "message size) — regenerate via ``PYTHONPATH=src python -m "
            "benchmarks.run --suite participation --suite comm`` and "
            "``experiments/update_tables.py``.  The ``*/p0.1`` rows are "
            "the sparse-participation stress point (10% of clients per "
            "round): the variance-reduction solvers (``scaffold``, "
            "``dfedtrack``) hold accuracy where plain gossip SGD "
            "(``dpsgd``) collapses, at the cost of a second "
            "full-precision gossip message per round (doubled "
            "bytes/round).\n\n" + table)
    text = _replace_section(text, "<!-- PARTICIPATION_COMM -->",
                            r"\n<!-- |\n## |\Z", body)
    print("participation x compression table updated")
    return text


def network_table(rows):
    """(algorithm, codec) x network preset -> time-to-target vs
    rounds-to-target — the wall-clock view the bytes column of the
    participation table cannot express (a codec that loses the rounds
    race can still win the clock on a slow network)."""
    lines = [
        "| algo | codec | network | acc | rounds-to-target | "
        "time-to-target | sim s/round | bytes/round |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for name, us, f in rows:
        parts = name.split("/")
        if len(parts) != 4 or parts[0] != "net" or "acc" not in f:
            continue
        _, algo, codec, preset = parts
        rt_key = next((k for k in f if k.startswith("rounds_to")), None)
        tt_key = next((k for k in f if k.startswith("time_to")), None)
        extra = (f" (part. {f['participation']})"
                 if "participation" in f else "")
        lines.append(
            f"| {algo}{extra} | {codec} | {preset} | {f['acc']} | "
            f"{f[rt_key] if rt_key else '-'} | "
            f"{f[tt_key] if tt_key else '-'} | "
            f"{f.get('sim_s_per_round', '-')} | "
            f"{f.get('bytes_per_round', '-')} |")
    if len(lines) == 2:
        return None
    return "\n".join(lines)


def update_network(text):
    path = os.path.join(ART_DIR, "network.csv")
    if not os.path.exists(path):
        print(f"no {path}; skipping network time-to-target table "
              "(generate it with: PYTHONPATH=src python -m benchmarks.run "
              "--suite net > " + path + ")")
        return text
    table = network_table(_parse_bench_csv(path))
    if table is None:
        print(f"{path} has no net rows; skipping")
        return text
    body = ("Time-to-target accuracy under the per-link network cost "
            "model (``repro.core.network``): modeled wall-clock seconds "
            "until the eval accuracy first reaches the target, next to "
            "the rounds-to-target the repo measured before — regenerate "
            "via ``PYTHONPATH=src python -m benchmarks.run --suite net`` "
            "and ``experiments/update_tables.py``.  The deadline rows "
            "couple the model back into participation: clients whose "
            "modeled transfer misses the round deadline sit the round "
            "out.\n\n" + table)
    text = _replace_section(text, "<!-- NETWORK_TIME -->",
                            r"\n<!-- |\n## |\Z", body)
    print("network time-to-target table updated")
    return text


def async_table(rows):
    """Execution mode x network preset -> time-to-target: synchronous
    rounds (full and deadline-masked) against the event-driven async
    engine (``repro.core.async_engine``) on the heterogeneous presets."""
    lines = [
        "| execution | network | acc | rounds/ticks-to-target | "
        "time-to-target | sim s/step | notes |",
        "|---|---|---|---|---|---|---|",
    ]
    for name, us, f in rows:
        parts = name.split("/")
        if len(parts) != 3 or parts[0] != "async" or "acc" not in f:
            continue
        _, mode, preset = parts
        rt_key = next((k for k in f if k.startswith(("rounds_to",
                                                     "ticks_to"))), None)
        tt_key = next((k for k in f if k.startswith("time_to")), None)
        step = f.get("sim_s_per_round", f.get("sim_s_per_tick", "-"))
        notes = []
        if "participation" in f:
            notes.append(f"part. {f['participation']}")
        if "mean_ticked" in f:
            notes.append(f"ticked {f['mean_ticked']}")
        if "max_staleness" in f:
            notes.append(f"staleness<={f['max_staleness']}")
        lines.append(
            f"| {mode} | {preset} | {f['acc']} | "
            f"{f[rt_key] if rt_key else '-'} | "
            f"{f[tt_key] if tt_key else '-'} | {step} | "
            f"{', '.join(notes) or '-'} |")
    if len(lines) == 2:
        return None
    return "\n".join(lines)


def update_async(text):
    path = os.path.join(ART_DIR, "async.csv")
    if not os.path.exists(path):
        print(f"no {path}; skipping async execution table "
              "(generate it with: PYTHONPATH=src python -m benchmarks.run "
              "--suite async > " + path + ")")
        return text
    table = async_table(_parse_bench_csv(path))
    if table is None:
        print(f"{path} has no async rows; skipping")
        return text
    body = ("Event-driven execution against synchronous rounds on the "
            "heterogeneous presets: each async client re-enters the "
            "gossip as soon as its own modeled compute + transfer "
            "completes (bounded-staleness mixing, "
            "``repro.core.async_engine``), so stragglers stop taxing the "
            "whole federation without being frozen out the way the "
            "deadline mask freezes them — regenerate via ``PYTHONPATH=src "
            "python -m benchmarks.run --suite async`` and "
            "``experiments/update_tables.py``.\n\n" + table)
    text = _replace_section(text, "<!-- ASYNC_TIME -->",
                            r"\n<!-- |\n## |\Z", body)
    print("async execution table updated")
    return text


def robust_table(rows):
    """Attack scenario x aggregator -> accuracy under Byzantine clients
    (``repro.core.threat``), plus the DP codec's privacy/utility points;
    the headline row pins trimmed-mean holding the target where plain
    mean collapses."""
    lines = [
        "| scenario | aggregator | acc | rounds-to-target | notes |",
        "|---|---|---|---|---|",
    ]
    for name, us, f in rows:
        parts = name.split("/")
        if len(parts) != 3 or parts[0] != "robust":
            continue
        _, scenario, variant = parts
        if scenario == "headline":
            rt = next((f[k] for k in f
                       if k.startswith("trimmed_mean_rounds_to")), "-")
            lines.append(f"| headline ({variant}) | trimmed_mean vs mean "
                         f"| - | {rt} | holds={f.get('holds', '-')} |")
            continue
        if "acc" not in f:
            continue
        rt_key = next((k for k in f if k.startswith("rounds_to")), None)
        notes = []
        if "adversaries" in f:
            notes.append(f"adversaries {f['adversaries']}")
        if "clip" in f:
            notes.append(f"clip {f['clip']}, noise x{f['noise_mult']}, "
                         f"clipped {f['clip_frac']}")
        agg = variant if scenario != "dp" else f"mean ({variant} dp)"
        lines.append(
            f"| {scenario} | {agg} | {f['acc']} | "
            f"{f[rt_key] if rt_key else '-'} | {', '.join(notes) or '-'} |")
    if len(lines) == 2:
        return None
    return "\n".join(lines)


def update_robust(text):
    path = os.path.join(ART_DIR, "robust.csv")
    if not os.path.exists(path):
        print(f"no {path}; skipping robustness table "
              "(generate it with: PYTHONPATH=src python -m benchmarks.run "
              "--suite robust > " + path + ")")
        return text
    table = robust_table(_parse_bench_csv(path))
    if table is None:
        print(f"{path} has no robust rows; skipping")
        return text
    body = ("Byzantine attacks against robust transport-level mixing "
            "(``repro.core.threat``): 20% of clients sign-flip their "
            "outgoing gossip messages each round; every honest receiver "
            "aggregates its neighbourhood with the chosen robust "
            "aggregator.  The dp rows run the ``dp`` wire codec (per-"
            "client L2 clip + Gaussian noise on the error-feedback path) "
            "with no attack — regenerate via ``PYTHONPATH=src python -m "
            "benchmarks.run --suite robust`` and "
            "``experiments/update_tables.py``.\n\n" + table)
    text = _replace_section(text, "<!-- ROBUST -->",
                            r"\n<!-- |\n## |\Z", body)
    print("robustness table updated")
    return text


def scale_table(rows):
    """Virtual-population scale-out (device footprint must stay flat as
    the population grows) plus the two-tier hier transport against flat
    dense gossip under the cluster-aware hub-and-spoke model."""
    lines = [
        "| scenario | us/round | device kB | store rows | "
        "sim s/round | notes |",
        "|---|---|---|---|---|---|",
    ]
    for name, us, f in rows:
        parts = name.split("/")
        if len(parts) != 3 or parts[0] != "scale":
            continue
        _, kind, point = parts
        notes = []
        if "cohort" in f:
            notes.append(f"cohort {f['cohort']}")
        if "acc" in f:
            notes.append(f"acc {f['acc']}")
        if "xdense" in f:
            notes.append(f"{f['xdense']}x of dense sim time")
        if "ticked" in f:
            notes.append(f"ticked {f['ticked']}")
        lines.append(
            f"| {kind}/{point} | {us:.0f} | {f.get('device_kb', '-')} | "
            f"{f.get('store_rows', '-')} | "
            f"{f.get('sim_time_per_round', '-')} | "
            f"{', '.join(notes) or '-'} |")
    if len(lines) == 2:
        return None
    return "\n".join(lines)


def update_scale(text):
    path = os.path.join(ART_DIR, "scale.csv")
    if not os.path.exists(path):
        print(f"no {path}; skipping cohort scale table "
              "(generate it with: PYTHONPATH=src python -m benchmarks.run "
              "--suite scale > " + path + ")")
        return text
    table = scale_table(_parse_bench_csv(path))
    if table is None:
        print(f"{path} has no scale rows; skipping")
        return text
    body = ("Cohort virtualization (``repro.core.cohort``): the virtual "
            "population lives host-side in the ``ClientStore`` and only "
            "a fixed hot cohort is device-resident per round, so the "
            "``device kB`` column stays flat while the population grows "
            "100x.  The hier/dense rows price two-tier hierarchical "
            "gossip (dense intra-cluster + head backbone) against flat "
            "dense gossip over the same cluster-aware hub-and-spoke "
            "links — the two-tier schedule rides only the fast links, "
            "so its modeled round time undercuts flat dense — "
            "regenerate via ``PYTHONPATH=src python -m benchmarks.run "
            "--suite scale`` and ``experiments/update_tables.py``.\n\n"
            + table)
    text = _replace_section(text, "<!-- SCALE -->",
                            r"\n<!-- |\n## |\Z", body)
    print("cohort scale table updated")
    return text


def main():
    text = open(MD_PATH).read() if os.path.exists(MD_PATH) else \
        "# EXPERIMENTS\n"
    text = update_roofline(text)
    text = update_participation(text)
    text = update_network(text)
    text = update_async(text)
    text = update_robust(text)
    text = update_scale(text)
    open(MD_PATH, "w").write(text)


if __name__ == "__main__":
    main()
