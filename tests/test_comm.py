"""Communication layer: Transport/MessageCodec API, push-sum weight
correction, codec round-trips + error feedback, quantize kernel vs
oracle, and bit-identity of the refactored paths against the seed
behaviour."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import comm, gossip, mixing
from repro.core.dfl import DFLConfig, init_state, make_train_round, simulate
from repro.core.participation import ParticipationSpec

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
_HAS_AXIS_TYPE = hasattr(jax.sharding, "AxisType")


def _tree(seed=0, m=6, shapes=((3, 4), (7,))):
    rng = np.random.default_rng(seed)
    return {f"l{i}": jnp.asarray(rng.normal(size=(m,) + s), jnp.float32)
            for i, s in enumerate(shapes)}


# ---------------------------------------------------------------------------
# Directed gossip matrices
# ---------------------------------------------------------------------------

def test_directed_ring_is_column_stochastic_not_symmetric():
    spec = gossip.make_gossip("dring", 8)
    gossip.validate_column_stochastic(spec.matrix)
    assert not np.allclose(spec.matrix, spec.matrix.T)
    with pytest.raises(ValueError):
        gossip.validate_gossip_matrix(spec.matrix)  # not symmetric


def test_directed_random_has_unequal_out_degrees():
    spec = gossip.make_gossip("drandom", 12, degree=3, seed=0)
    gossip.validate_column_stochastic(spec.matrix)
    row_sums = spec.matrix.sum(axis=1)
    assert not np.allclose(row_sums, 1.0)       # genuinely not doubly stoch.


def test_as_column_stochastic_conventions():
    # irregular digraph: column- but NOT row-stochastic, so the two
    # conventions are distinguishable
    p = gossip.make_gossip("drandom", 9, degree=3, seed=5).matrix
    assert not np.allclose(p.sum(axis=1), 1.0)
    np.testing.assert_array_equal(gossip.as_column_stochastic(p), p)
    # row-stochastic input is re-expressed in the sender convention
    np.testing.assert_array_equal(gossip.as_column_stochastic(p.T), p)
    # doubly stochastic passes through unchanged
    w = gossip.make_gossip("ring", 6).matrix
    np.testing.assert_array_equal(gossip.as_column_stochastic(w), w)
    with pytest.raises(ValueError):
        gossip.as_column_stochastic(np.eye(4) * 0.5)


def test_mask_and_renormalize_columns_properties():
    p = gossip.make_gossip("drandom", 10, degree=3, seed=1).matrix
    active = np.ones(10, dtype=bool)
    active[[2, 5, 6]] = False
    pm = gossip.mask_and_renormalize_columns(p, active)
    gossip.validate_column_stochastic(pm)
    for i in np.flatnonzero(~active):
        e = np.zeros(10)
        e[i] = 1.0
        np.testing.assert_array_equal(pm[i], e)
        np.testing.assert_array_equal(pm[:, i], e)
    with pytest.raises(ValueError):
        gossip.mask_and_renormalize_columns(p, active[:4])


def test_directed_topology_requires_pushsum():
    with pytest.raises(ValueError):
        DFLConfig(topology="dring")             # dense transport -> biased
    cfg = DFLConfig(topology="dring", transport="pushsum")
    assert cfg.transport == "pushsum"


# ---------------------------------------------------------------------------
# Config surface
# ---------------------------------------------------------------------------

def test_config_transport_resolution():
    assert DFLConfig().transport == "dense"
    assert DFLConfig(transport="ppermute").transport == "ppermute"
    for bad in (dict(transport="smoke-signals"), dict(codec="gzip"),
                dict(codec_bits=1), dict(codec_bits=9), dict(codec_k=0)):
        with pytest.raises(ValueError):
            DFLConfig(**bad)
    # the pre-redesign ``mixing`` alias is gone, not silently ignored
    with pytest.raises(TypeError):
        DFLConfig(mixing="dense")


# ---------------------------------------------------------------------------
# Codecs
# ---------------------------------------------------------------------------

def test_identity_codec_is_bit_exact_passthrough():
    z = _tree()
    codec = comm.IdentityCodec()
    wire, resid = codec.encode(z, None, None)
    assert codec.decode(wire) is z and resid is None
    assert codec.bytes_per_client({"a": jnp.zeros((3, 4))}) == 12 * 4


def test_int8_roundtrip_error_bound():
    """|decode(encode(z)) - z| < scale = absmax / qmax, per client."""
    z = _tree(seed=1)
    codec = comm.QuantizeCodec(bits=8)
    wire, _ = codec.encode(z, codec.init_state(z), jax.random.PRNGKey(0))
    zh = codec.decode(wire)
    for k in z:
        scale = np.asarray(wire[k]["scale"])          # (m,)
        err = np.abs(np.asarray(zh[k]) - np.asarray(z[k]))
        bound = scale.reshape((-1,) + (1,) * (z[k].ndim - 1))
        assert (err <= bound + 1e-7).all()
        assert zh[k].dtype == z[k].dtype


def test_low_bit_quantization_coarser_than_int8():
    z = _tree(seed=2)
    err = {}
    for bits in (8, 4):
        codec = comm.QuantizeCodec(bits=bits)
        wire, _ = codec.encode(z, None, jax.random.PRNGKey(0))
        zh = codec.decode(wire)
        err[bits] = max(float(jnp.max(jnp.abs(zh[k] - z[k]))) for k in z)
    assert err[4] > err[8]


def test_topk_roundtrip_keeps_largest_entries():
    z = _tree(seed=3)
    codec = comm.TopKCodec(k=5)
    wire, _ = codec.encode(z, None, None)
    zh = codec.decode(wire)
    for k in z:
        m = z[k].shape[0]
        flat = np.asarray(z[k]).reshape(m, -1)
        dec = np.asarray(zh[k]).reshape(m, -1)
        kk = min(5, flat.shape[1])
        for i in range(m):
            nz = np.flatnonzero(dec[i])
            assert len(nz) <= kk
            np.testing.assert_allclose(dec[i, nz], flat[i, nz], rtol=1e-6)
            # kept entries are the largest-magnitude ones
            thresh = np.sort(np.abs(flat[i]))[-kk]
            assert (np.abs(flat[i, nz]) >= thresh - 1e-6).all()


@pytest.mark.parametrize("codec_fn", [
    lambda: comm.QuantizeCodec(bits=4),
    lambda: comm.TopKCodec(k=3),
    lambda: comm.RandKCodec(k=3),
])
def test_error_feedback_telescopes(codec_fn):
    """sum_t decode(wire_t) == sum_t z_t + (r_0 - r_T): the compressed
    stream's running sum tracks the uncompressed one to within one
    residual, so the per-round compression error does not accumulate."""
    codec = codec_fn()
    rng = np.random.default_rng(4)
    key = jax.random.PRNGKey(1)
    resid = None
    sum_true = np.zeros((4, 6))
    sum_dec = np.zeros((4, 6))
    for t in range(25):
        z = {"p": jnp.asarray(rng.normal(size=(4, 6)), jnp.float32)}
        key, sub = jax.random.split(key)
        wire, resid = codec.encode(z, resid, sub)
        sum_true += np.asarray(z["p"])
        sum_dec += np.asarray(codec.decode(wire)["p"])
    final_resid = np.asarray(resid["p"])
    np.testing.assert_allclose(sum_dec + final_resid, sum_true,
                               rtol=1e-4, atol=1e-4)


def test_randk_shared_indices_roundtrip():
    """All clients keep the SAME randomly drawn coordinates (shared round
    seed — that is what keeps the sparsified messages mixable and the
    wire free of per-client index lists), and kept entries round-trip
    exactly."""
    z = _tree(seed=9)
    codec = comm.RandKCodec(k=4)
    wire, _ = codec.encode(z, codec.init_state(z), jax.random.PRNGKey(3))
    zh = codec.decode(wire)
    for k in z:
        idx = np.asarray(wire[k]["idx"])
        assert idx.ndim == 1 and len(set(idx.tolist())) == len(idx)
        m = z[k].shape[0]
        flat = np.asarray(z[k]).reshape(m, -1)
        dec = np.asarray(zh[k]).reshape(m, -1)
        kk = min(4, flat.shape[1])
        assert len(idx) == kk
        np.testing.assert_allclose(dec[:, idx], flat[:, idx], rtol=1e-6)
        # everything off the shared support is zero for every client
        mask = np.ones(flat.shape[1], bool)
        mask[idx] = False
        assert (dec[:, mask] == 0).all()


def test_randk_indices_change_with_round_key():
    z = _tree(seed=10, shapes=((64,),))
    codec = comm.RandKCodec(k=4)
    w1, _ = codec.encode(z, None, jax.random.PRNGKey(0))
    w2, _ = codec.encode(z, None, jax.random.PRNGKey(1))
    assert not np.array_equal(np.asarray(w1["l0"]["idx"]),
                              np.asarray(w2["l0"]["idx"]))


def test_randk_requires_rng():
    with pytest.raises(ValueError, match="codec PRNG"):
        comm.RandKCodec(k=2).encode(_tree(), None, None)


def test_codec_wire_bytes_accounting():
    params = {"a": jnp.zeros((100,), jnp.float32),
              "b": jnp.zeros((10, 10), jnp.float32)}
    assert comm.IdentityCodec().bytes_per_client(params) == 200 * 4
    assert comm.QuantizeCodec(bits=8).bytes_per_client(params) == 2 * (100 + 4)
    assert comm.QuantizeCodec(bits=4).bytes_per_client(params) == 2 * (50 + 4)
    assert comm.TopKCodec(k=16).bytes_per_client(params) == 2 * 16 * 8
    # rand-k ships values + one shared seed: ~half of top-k at equal k
    assert comm.RandKCodec(k=16).bytes_per_client(params) == 2 * (16 * 4 + 4)
    assert (comm.RandKCodec(k=16).bytes_per_client(params)
            < comm.TopKCodec(k=16).bytes_per_client(params))
    # >= 3x reduction for int8 on f32 leaves (the acceptance criterion)
    assert (comm.IdentityCodec().bytes_per_client(params)
            >= 3 * comm.QuantizeCodec(bits=8).bytes_per_client(params))


# ---------------------------------------------------------------------------
# Quantize kernel vs oracle
# ---------------------------------------------------------------------------

QSHAPES = [(4, 16), (8, 128), (3, 5, 17), (2, 513, 31)]


@pytest.mark.parametrize("shape", QSHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quantize_kernel_matches_ref(shape, dtype):
    from repro.kernels import ops, ref
    rng = np.random.default_rng(hash(shape) % 2**31)
    x = jnp.asarray(rng.normal(size=shape), dtype)
    u = jnp.asarray(rng.random(size=shape), jnp.float32)
    q, scale, r = ops.quantize_leaf(x, u, bits=8)
    m = shape[0]
    sb = scale.reshape((m,) + (1,) * (len(shape) - 1))
    qr, rr = ref.quantize_stochastic(x, sb, u, bits=8)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
    # bf16 residuals may differ by one ulp where XLA contracts x - q*s
    # into an FMA on one of the two paths
    tol = dict(rtol=1e-2, atol=1e-4) if dtype == jnp.bfloat16 else \
        dict(rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(r, np.float32),
                               np.asarray(rr, np.float32), **tol)
    y = ops.dequantize_leaf(q, scale, shape, dtype)
    yr = ref.dequantize(q.reshape(m, -1),
                        scale.reshape(-1, 1)).reshape(shape).astype(dtype)
    np.testing.assert_array_equal(np.asarray(y, np.float32),
                                  np.asarray(yr, np.float32))


def test_quantize_codec_kernel_path_matches_jnp_path():
    z = _tree(seed=5)
    key = jax.random.PRNGKey(7)
    wires, decs = [], []
    for use_kernel in (False, True):
        codec = comm.QuantizeCodec(bits=8, use_kernel=use_kernel)
        wire, resid = codec.encode(z, codec.init_state(z), key)
        wires.append(wire)
        decs.append(codec.decode(wire))
    for k in z:
        np.testing.assert_array_equal(np.asarray(wires[0][k]["q"]),
                                      np.asarray(wires[1][k]["q"]))
        np.testing.assert_allclose(np.asarray(decs[0][k]),
                                   np.asarray(decs[1][k]), rtol=1e-6)


# ---------------------------------------------------------------------------
# Push-sum transport
# ---------------------------------------------------------------------------

def test_pushsum_weights_converge_to_uniform_on_directed_ring():
    """On a directed ring the column-stochastic matrix is doubly
    stochastic, so the Perron vector is uniform: the per-client push-sum
    weight converges to (stays at) exactly 1/m."""
    m = 8
    spec = gossip.make_gossip("dring", m)
    t = comm.PushSumTransport()
    plan = t.prepare(spec)
    aux = t.init_aux(m)
    x = _tree(seed=6, m=m, shapes=((3,),))
    target = np.asarray(x["l0"]).mean(0)
    for _ in range(120):
        x, aux = t.mix(x, plan, aux)
    np.testing.assert_allclose(np.asarray(aux), 1.0 / m, atol=1e-6)
    np.testing.assert_allclose(np.asarray(x["l0"]),
                               np.broadcast_to(target, (m, 3)), atol=1e-4)


def test_pushsum_reaches_true_average_on_irregular_digraph():
    """The point of the weight correction: on a digraph with unequal
    out-degrees, weight-less mixing converges to a Perron-weighted
    average, push-sum to the true uniform average."""
    m = 10
    spec = gossip.make_gossip("drandom", m, degree=3, seed=2)
    t = comm.PushSumTransport()
    plan = t.prepare(spec)
    x = _tree(seed=7, m=m, shapes=((4,),))
    target = np.asarray(x["l0"]).mean(0)
    aux = t.init_aux(m)
    xn = {"l0": x["l0"]}
    p = np.asarray(spec.matrix)
    naive = np.asarray(x["l0"]).copy()
    for _ in range(300):
        xn, aux = t.mix(xn, plan, aux)
        naive = p @ naive
    assert not np.allclose(np.asarray(aux), 1.0 / m)   # non-uniform Perron
    np.testing.assert_allclose(np.asarray(xn["l0"]),
                               np.broadcast_to(target, (m, 4)), atol=1e-4)
    # the uncorrected iteration is measurably biased
    assert np.abs(naive - target[None]).max() > 1e-2


def test_pushsum_with_doubly_stochastic_matrix_is_plain_mixing():
    """Symmetric gossip under push-sum: weights stay exactly uniform and
    the step equals the dense einsum."""
    m = 6
    spec = gossip.make_gossip("exp", m)
    t = comm.PushSumTransport()
    plan = t.prepare(spec)
    z = _tree(seed=8, m=m)
    x, aux = t.mix(z, plan, t.init_aux(m))
    ref = mixing.mix_dense(jnp.asarray(spec.matrix, jnp.float32), z)
    for k in z:
        np.testing.assert_allclose(np.asarray(x[k]), np.asarray(ref[k]),
                                   rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(np.asarray(aux), 1.0 / m, rtol=1e-6)


def test_pushsum_mix_requires_aux():
    spec = gossip.make_gossip("dring", 4)
    t = comm.PushSumTransport()
    with pytest.raises(ValueError):
        t.mix(_tree(m=4), t.prepare(spec), None)


# ---------------------------------------------------------------------------
# End-to-end rounds
# ---------------------------------------------------------------------------

def _lin_setup(m=4, K=3, seed=0):
    rng = np.random.default_rng(seed)
    params = {"w": jnp.asarray(rng.normal(size=(5, 2)) / 2, jnp.float32)}
    batches = {"x": jnp.asarray(rng.normal(size=(m, K, 8, 5)), jnp.float32),
               "y": jnp.asarray(rng.normal(size=(m, K, 8, 2)), jnp.float32)}

    def loss(p, batch, r):
        return jnp.mean((batch["x"] @ p["w"] - batch["y"]) ** 2)

    def sampler(t):
        r = np.random.default_rng(100 + t)
        return {"x": jnp.asarray(r.normal(size=(m, K, 8, 5)), jnp.float32),
                "y": jnp.asarray(r.normal(size=(m, K, 8, 2)), jnp.float32)}

    return params, batches, loss, sampler


def test_quantized_pushsum_round_smoke():
    """Fast-tier smoke: one jitted quantized push-sum round end-to-end."""
    m, K = 4, 3
    params, batches, loss, _ = _lin_setup(m, K)
    cfg = DFLConfig(algorithm="dfedadmm", m=m, K=K, lam=0.2,
                    topology="dring", transport="pushsum", codec="int8",
                    codec_bits=4)
    spec = gossip.make_gossip("dring", m)
    state = init_state(params, cfg, seed=0)
    assert set(state.comm) == {"ps_weight", "residual"}
    round_fn = jax.jit(make_train_round(loss, cfg, spec=spec,
                                        metrics="light"))
    plan = comm.PushSumTransport().prepare(spec)
    new_state, metrics = round_fn(state, batches, plan)
    assert np.isfinite(float(metrics["loss"]))
    assert not np.array_equal(np.asarray(new_state.params["w"]),
                              np.asarray(state.params["w"]))
    # weights stay uniform on the directed ring (doubly stochastic)
    np.testing.assert_allclose(np.asarray(new_state.comm["ps_weight"]),
                               1.0 / m, rtol=1e-6)
    # residual state engaged
    assert any(float(jnp.max(jnp.abs(l))) > 0
               for l in jax.tree.leaves(new_state.comm["residual"]))


def test_dense_identity_bit_identical_to_seed_path():
    """transport='dense' + codec='identity' through the comm API is the
    pre-PR mixing path bit for bit (same jitted computation)."""
    m, K = 4, 3
    params, _, loss, sampler = _lin_setup(m, K)
    base = dict(algorithm="dfedadmm", m=m, K=K, lam=0.2, topology="ring")
    s_a, h_a = simulate(loss, None, params, DFLConfig(**base), sampler,
                        rounds=5)
    s_b, h_b = simulate(loss, None, params,
                        DFLConfig(**base, transport="dense",
                                  codec="identity"), sampler, rounds=5)
    s_c, h_c = simulate(loss, None, params,
                        DFLConfig(**base, transport="dense"), sampler,
                        rounds=5)
    for s in (s_b, s_c):
        np.testing.assert_array_equal(np.asarray(s_a.params["w"]),
                                      np.asarray(s.params["w"]))
    np.testing.assert_array_equal(np.asarray(h_a["loss"]),
                                  np.asarray(h_b["loss"]))


def test_ppermute_identity_bit_identical_to_dense_fallback():
    """transport='ppermute' without a mesh takes the dense fallback
    against the static circulant matrix — the seed behaviour."""
    m, K = 4, 2
    params, batches, loss, _ = _lin_setup(m, K)
    spec = gossip.make_gossip("ring", m)
    outs = {}
    for name in ("dense", "ppermute"):
        cfg = DFLConfig(algorithm="dfedavg", m=m, K=K, topology="ring",
                        transport=name)
        round_fn = jax.jit(make_train_round(loss, cfg, spec=spec,
                                            metrics="light"))
        state = init_state(params, cfg, seed=0)
        plan = comm.make_transport(cfg, spec=spec).prepare(spec)
        st, _ = round_fn(state, batches, plan)
        outs[name] = np.asarray(st.params["w"])
    np.testing.assert_array_equal(outs["dense"], outs["ppermute"])


def test_ppermute_prepare_rejects_foreign_matrix():
    """The invariant holds below simulate() too: feeding a different
    round matrix straight into PpermuteTransport.prepare raises instead
    of silently gossiping over the construction-time graph."""
    m = 8
    spec0 = gossip.make_gossip("random", m, degree=3, seed=0)
    if not spec0.is_circulant():
        spec_ring = gossip.make_gossip("ring", m)
        t = comm.PpermuteTransport(spec_ring)
        with pytest.raises(ValueError, match="cannot realize"):
            t.prepare(spec0)
    # same matrix (fresh spec object) is fine
    t = comm.PpermuteTransport(gossip.make_gossip("ring", m))
    assert t.prepare(gossip.make_gossip("ring", m)) is None


def test_simulate_rejects_time_varying_ppermute():
    """Regression for the silent specs[0]-reuse bug: random topology +
    ppermute must raise instead of gossiping over round 0's graph."""
    m, K = 4, 2
    params, _, loss, sampler = _lin_setup(m, K)
    cfg = DFLConfig(algorithm="dfedavg", m=m, K=K, topology="random",
                    transport="ppermute")
    with pytest.raises(ValueError, match="static neighbour pattern"):
        simulate(loss, None, params, cfg, sampler, rounds=3)


def test_wire_bytes_history_scales_with_participation():
    m, K = 6, 2
    params, _, loss, sampler = _lin_setup(m, K)
    cfg = DFLConfig(algorithm="dfedavg", m=m, K=K, topology="full",
                    codec="int8",
                    participation=ParticipationSpec(mode="fraction", p=0.5))
    _, hist = simulate(loss, None, params, cfg, sampler, rounds=3)
    bpc = comm.QuantizeCodec(bits=8).bytes_per_client(params)
    assert hist["wire_bytes"] == [bpc * 3] * 3      # 3 of 6 clients active


def test_masked_quantized_round_holds_inactive_state():
    """Compression noise must not leak into inactive clients: their
    parameters and codec residuals stay bitwise frozen."""
    m, K = 6, 2
    params, batches, loss, _ = _lin_setup(m, K)
    cfg = DFLConfig(algorithm="dfedavg", m=m, K=K, topology="full",
                    codec="int8",
                    participation=ParticipationSpec(mode="fraction", p=0.5))
    spec = gossip.make_gossip("full", m)
    state = init_state(params, cfg, seed=0)
    active = np.array([True, False, True, False, True, True])
    steps = np.where(active, K, 0).astype(np.int32)
    round_fn = jax.jit(make_train_round(loss, cfg, spec=spec,
                                        metrics="light"))
    plan = comm.DenseTransport().prepare(spec, active)
    st, _ = round_fn(state, batches, plan, jnp.asarray(active),
                     jnp.asarray(steps))
    for i in np.flatnonzero(~active):
        np.testing.assert_array_equal(np.asarray(st.params["w"][i]),
                                      np.asarray(state.params["w"][i]))
        np.testing.assert_array_equal(
            np.asarray(st.comm["residual"]["w"][i]),
            np.asarray(state.comm["residual"]["w"][i]))


@pytest.mark.parametrize("use_kernel", ["comm", True])
@pytest.mark.parametrize("masked", [False, True])
def test_fused_quantized_gossip_bit_identical_to_composed(use_kernel, masked):
    """The fused quantize+EF+mix Pallas round through ``simulate`` is the
    composed encode -> decode -> mix path bit for bit — both consume the
    same fold_in-derived uniform draws, so stochastic rounding picks the
    same integers.  ``use_kernel='comm'`` fuses only the wire path;
    ``True`` additionally routes the solver kernels."""
    m, K = 6, 2
    params, _, loss, sampler = _lin_setup(m, K)
    part = ParticipationSpec(mode="fraction", p=0.5) if masked else \
        ParticipationSpec()
    base = dict(algorithm="dfedadmm", m=m, K=K, lam=0.2, topology="full",
                codec="int8", codec_bits=8, participation=part)
    s_a, h_a = simulate(loss, None, params, DFLConfig(**base), sampler,
                        rounds=5, seed=3)
    s_b, h_b = simulate(loss, None, params,
                        DFLConfig(**base, use_kernel=use_kernel), sampler,
                        rounds=5, seed=3)
    np.testing.assert_array_equal(np.asarray(s_a.params["w"]),
                                  np.asarray(s_b.params["w"]))
    np.testing.assert_array_equal(np.asarray(s_a.comm["residual"]["w"]),
                                  np.asarray(s_b.comm["residual"]["w"]))
    np.testing.assert_array_equal(np.asarray(h_a["loss"]),
                                  np.asarray(h_b["loss"]))
    assert h_a["wire_bytes"] == h_b["wire_bytes"]   # same modeled wire


def test_config_rejects_unknown_use_kernel():
    with pytest.raises(ValueError):
        DFLConfig(use_kernel="codec")


@pytest.mark.slow
def test_pushsum_converges_like_symmetric_gossip():
    """Acceptance: a directed-ring push-sum run converges to the same
    loss as symmetric ring gossip within tolerance."""
    m, K = 8, 3
    params, _, loss, sampler = _lin_setup(m, K)
    _, h_sym = simulate(loss, None, params,
                        DFLConfig(algorithm="dfedadmm", m=m, K=K, lam=0.2,
                                  topology="ring"), sampler, rounds=15)
    _, h_ps = simulate(loss, None, params,
                       DFLConfig(algorithm="dfedadmm", m=m, K=K, lam=0.2,
                                 topology="dring", transport="pushsum"),
                       sampler, rounds=15)
    assert h_ps["loss"][-1] < h_ps["loss"][0]
    assert abs(h_ps["loss"][-1] - h_sym["loss"][-1]) \
        <= 0.1 * abs(h_sym["loss"][-1]) + 0.05


@pytest.mark.slow
def test_quantized_gossip_still_converges():
    """Error feedback keeps the compressed run within tolerance of the
    uncompressed one at equal rounds."""
    m, K = 8, 3
    params, _, loss, sampler = _lin_setup(m, K)
    base = dict(algorithm="dfedadmm", m=m, K=K, lam=0.2, topology="ring")
    _, h_id = simulate(loss, None, params, DFLConfig(**base), sampler,
                       rounds=15)
    _, h_q = simulate(loss, None, params,
                      DFLConfig(**base, codec="int8", codec_bits=4),
                      sampler, rounds=15)
    assert h_q["loss"][-1] < h_q["loss"][0]
    assert h_q["loss"][-1] <= 1.2 * h_id["loss"][-1] + 0.05


_MASKED_PPERMUTE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import AxisType
from repro.core import gossip, mixing

mesh = jax.make_mesh((8,), ("data",), axis_types=(AxisType.Auto,))
for topo in ("ring", "exp", "full"):
    spec = gossip.make_gossip(topo, 8)
    active = np.array([True, False, True, True, False, True, True, True])
    z = {"a": jnp.asarray(np.random.default_rng(0).normal(size=(8, 4, 6)),
                          jnp.float32)}
    wm = gossip.mask_and_renormalize(spec.matrix, active)
    dense = mixing.mix_dense(jnp.asarray(wm, jnp.float32), z)
    gates, self_w = mixing.ppermute_gates(spec, active)
    pp = mixing.mix_ppermute_masked(z, jnp.asarray(gates),
                                    jnp.asarray(self_w), spec, mesh, "data")
    np.testing.assert_allclose(np.asarray(pp["a"]), np.asarray(dense["a"]),
                               rtol=1e-5, atol=1e-6)
print("MASKED_PPERMUTE_OK")
"""


@pytest.mark.slow
@pytest.mark.skipif(not _HAS_AXIS_TYPE,
                    reason="jax.sharding.AxisType unavailable in this jax")
def test_masked_ppermute_equals_masked_dense_subprocess():
    """Gated permute sends realize mask_and_renormalize on the sharded
    substrate (the ROADMAP item: participation on the ppermute path)."""
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _MASKED_PPERMUTE_SCRIPT],
                       env=env, capture_output=True, text=True, timeout=300)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "MASKED_PPERMUTE_OK" in r.stdout


# ---------------------------------------------------------------------------
# fp8 e4m3 codec
# ---------------------------------------------------------------------------

def test_fp8_wire_matches_ml_dtypes_oracle():
    """The on-wire payload must be bit-identical to a pure-numpy
    ml_dtypes reference: scale = absmax/448 per client, clip to the e4m3
    range, round-to-nearest-even cast."""
    import ml_dtypes
    z = _tree(seed=11)
    codec = comm.Fp8Codec()
    wire, resid = codec.encode(z, codec.init_state(z), None)
    for k in z:
        e = np.asarray(z[k], np.float32)
        m = e.shape[0]
        absmax = np.abs(e).reshape(m, -1).max(axis=1)
        scale = np.maximum(absmax, 1e-12) / np.float32(448.0)
        sb = scale.reshape((m,) + (1,) * (e.ndim - 1))
        qref = np.clip(e / sb, -448.0, 448.0).astype(ml_dtypes.float8_e4m3fn)
        got = np.asarray(wire[k]["q"])
        assert got.dtype == ml_dtypes.float8_e4m3fn
        np.testing.assert_array_equal(got.view(np.uint8),
                                      qref.view(np.uint8))
        np.testing.assert_allclose(np.asarray(wire[k]["scale"]), scale,
                                   rtol=1e-6)
        # the residual is exactly the cast error (EF telescopes it away)
        rref = e - qref.astype(np.float32) * sb
        np.testing.assert_allclose(np.asarray(resid[k]), rref, atol=1e-7)


def test_fp8_never_nan_on_extreme_values():
    """XLA's float8 cast overflows to NaN, not saturation: the absmax
    element sits exactly on the clip boundary and must survive."""
    z = {"a": jnp.asarray([[1e30, -1e30, 3.0], [1e-20, 0.0, -1e-20]],
                          jnp.float32)}
    codec = comm.Fp8Codec()
    wire, resid = codec.encode(z, codec.init_state(z), None)
    q = np.asarray(wire["a"]["q"], np.float32)
    assert np.isfinite(q).all()
    assert np.isfinite(np.asarray(resid["a"])).all()
    zh = codec.decode(wire)
    assert np.isfinite(np.asarray(zh["a"])).all()
    # the per-client absmax element decodes exactly (448 * scale)
    np.testing.assert_allclose(np.asarray(zh["a"])[0, 0], 1e30, rtol=1e-6)


def test_fp8_relative_error_bound():
    """e4m3 has a 3-bit mantissa: every decoded value is within 2^-4 of
    the original relative to the per-client scale ceiling."""
    z = _tree(seed=12)
    codec = comm.Fp8Codec()
    wire, _ = codec.encode(z, codec.init_state(z), None)
    zh = codec.decode(wire)
    for k in z:
        x = np.asarray(z[k], np.float32)
        err = np.abs(np.asarray(zh[k]) - x)
        # RNE on e4m3: |err| <= max(|x| * 2^-4, smallest step * scale)
        scale = np.asarray(wire[k]["scale"]).reshape(
            (-1,) + (1,) * (x.ndim - 1))
        bound = np.maximum(np.abs(x) * 2.0 ** -4, scale * 2.0 ** -9)
        assert (err <= bound + 1e-9).all()
        assert zh[k].dtype == z[k].dtype


def test_fp8_bytes_per_client():
    params = {"a": jnp.zeros((10, 10)), "b": jnp.zeros((7,))}
    assert comm.Fp8Codec().bytes_per_client(params) == (100 + 4) + (7 + 4)
    assert "fp8" in comm.CODECS


def test_fp8_error_feedback_reduces_bias_over_rounds():
    """With EF the mean decoded message over rounds converges to the mean
    input (the deterministic RNE bias telescopes)."""
    z = _tree(seed=13, shapes=((64,),))
    codec = comm.Fp8Codec()
    resid = codec.init_state(z)
    acc = np.zeros_like(np.asarray(z["l0"]))
    rounds = 64
    for _ in range(rounds):
        wire, resid = codec.encode(z, resid, None)
        acc += np.asarray(codec.decode(wire)["l0"])
    bias = np.abs(acc / rounds - np.asarray(z["l0"])).max()
    one_shot = np.abs(
        np.asarray(codec.decode(codec.encode(z, codec.init_state(z),
                                             None)[0])["l0"])
        - np.asarray(z["l0"])).max()
    assert bias < one_shot / 4


def test_fp8_simulate_end_to_end():
    cfg = DFLConfig(m=4, K=2, topology="ring", lr=0.05, codec="fp8")
    params = {"w": jnp.zeros((3, 1)), "b": jnp.zeros((1,))}

    def loss_fn(p, batch, rng):
        x, y = batch
        return jnp.mean((x @ p["w"] + p["b"] - y) ** 2)

    def sample(t):
        rng = np.random.default_rng((5, t))
        x = rng.standard_normal((4, 2, 4, 3)).astype(np.float32)
        y = (x.sum(-1, keepdims=True) * 0.5).astype(np.float32)
        return (jnp.asarray(x), jnp.asarray(y))

    state, hist = simulate(loss_fn, None, params, cfg, sample, rounds=10,
                           seed=0)
    assert np.isfinite(hist["loss"]).all()
    assert hist["loss"][-1] < hist["loss"][0]
    assert "residual" in state.comm


# ---------------------------------------------------------------------------
# hier transport
# ---------------------------------------------------------------------------

def test_hier_tier_matrices_are_definition1():
    from repro.core import gossip
    w_intra, w_inter = gossip.hier_tier_matrices(12, 3)
    for w in (w_intra, w_inter):
        gossip.validate_gossip_matrix(w)          # raises if not Def-1
    # intra never crosses clusters; inter only couples heads
    labels = gossip.cluster_labels(12, 3)
    heads = gossip.cluster_heads(labels)
    off = np.flatnonzero(w_intra - np.diag(np.diag(w_intra)))
    for idx in off:
        i, j = divmod(idx, 12)
        assert labels[i] == labels[j]
    off = np.argwhere(w_inter - np.diag(np.diag(w_inter)))
    assert set(np.unique(off)) <= set(heads.tolist())


def test_hier_mix_is_two_sequential_dense_steps():
    cfg = DFLConfig(m=8, topology="ring", transport="hier", clusters=2)
    transport = comm.make_transport(cfg)
    z = _tree(seed=21, m=8)
    plan = transport.prepare(None)
    out, _ = transport.mix(z, plan)
    from repro.core import mixing
    ref = mixing.mix_dense(np.asarray(plan["inter"]),
                           mixing.mix_dense(np.asarray(plan["intra"]), z))
    for k in z:
        np.testing.assert_allclose(np.asarray(out[k]), np.asarray(ref[k]),
                                   rtol=1e-5, atol=1e-6)
    # mean preservation through both tiers
    np.testing.assert_allclose(
        np.mean(np.asarray(out["l0"]), 0),
        np.mean(np.asarray(z["l0"]), 0), atol=1e-5)


def test_hier_masked_participation_and_tier_pricing():
    from repro.core.network import make_network
    cfg = DFLConfig(m=8, topology="ring", transport="hier", clusters=2,
                    participation=ParticipationSpec(mode="fraction", p=0.5))
    transport = comm.make_transport(cfg)
    active = np.array([1, 1, 0, 1, 0, 1, 1, 0], bool)
    plan = transport.prepare(None, active)
    for tier in ("intra", "inter"):
        w = np.asarray(plan[tier])
        # inactive rows are identity (their state passes through)
        for i in np.flatnonzero(~active):
            row = np.zeros(8)
            row[i] = 1.0
            np.testing.assert_allclose(w[i], row, atol=1e-7)
    tiers = transport.sim_tiers(None, active)
    assert len(tiers) == 2
    net = make_network("hub-and-spoke", 8, seed=0, hubs=2)
    t_hier = net.tiered_round_time(tiers, 1000, 0, 1, active=active)
    assert np.isfinite(t_hier) and t_hier > 0


def test_hier_beats_flat_dense_on_cluster_network():
    """The acceptance property: under the cluster-aware hub-and-spoke
    model, two-tier gossip (fast intra links + head backbone) is modeled
    faster than flat dense gossip over the same graph distances."""
    from repro.core.network import make_network
    m, clusters, nbytes = 16, 4, 10_000
    net = make_network("hub-and-spoke", m, seed=0, hubs=clusters)
    cfg = DFLConfig(m=m, topology="full", transport="hier",
                    clusters=clusters)
    tiers = comm.make_transport(cfg).sim_tiers(None)
    t_hier = net.tiered_round_time(tiers, nbytes, 0, 1)
    from repro.core import gossip
    w_full = gossip.make_gossip("full", m).matrix
    t_dense = net.round_time(w_full, nbytes, 0, 1)
    assert t_hier < t_dense
