"""Cohort virtualization: ClientStore gather/scatter, bit-identity of the
full-population cohort against the dense simulate path for every
registered solver, and cohort-sampling determinism."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import solver_names
from repro.core.cohort import ClientStore, simulate_virtual
from repro.core.dfl import DFLConfig, simulate
from repro.core.participation import ParticipationSpec, cohort_ids

M = 6


def loss_fn(params, batch, rng):
    x, y = batch
    pred = x @ params["w"] + params["b"]
    return jnp.mean((pred - y) ** 2)


def make_params():
    return {"w": jnp.zeros((3, 2)), "b": jnp.zeros((2,))}


def make_sampler(m, seed=0):
    def sample(t):
        rng = np.random.default_rng((seed, t))
        x = rng.standard_normal((m, 2, 4, 3)).astype(np.float32)
        y = np.tanh(x @ rng.standard_normal((3, 2)).astype(np.float32))
        return (jnp.asarray(x), jnp.asarray(y.astype(np.float32)))
    return sample


def cohort_sampler(seed=0):
    def sample(t, ids):
        rng = np.random.default_rng((seed, t))
        x = rng.standard_normal((len(ids), 2, 4, 3)).astype(np.float32)
        y = np.tanh(x @ rng.standard_normal((3, 2)).astype(np.float32))
        return (jnp.asarray(x), jnp.asarray(y.astype(np.float32)))
    return sample


def _tree_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# bit-identity: cohort == population must reproduce the dense path exactly
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algorithm", solver_names("dfl"))
def test_full_cohort_bit_identical_to_dense(algorithm):
    kw = dict(m=M, K=2, algorithm=algorithm, topology="ring", lr=0.05)
    sd, hd = simulate(loss_fn, None, make_params(), DFLConfig(**kw),
                      make_sampler(M), rounds=4, seed=1)
    sv, hv = simulate(loss_fn, None, make_params(),
                      DFLConfig(n_virtual=M, **kw),
                      make_sampler(M), rounds=4, seed=1)
    _tree_equal(sd.params, sv.params)
    _tree_equal(sd.solver, sv.solver)
    # comm covers the gossip-carried tracking buffer of the
    # variance-reduction family (scaffold / dfedtrack); None == None
    # for the stateless rest
    _tree_equal(sd.comm, sv.comm)
    assert hd["loss"] == hv["loss"]
    assert hd["consensus_sq"] == hv["consensus_sq"]
    assert hd["dual_norm"] == hv["dual_norm"]


@pytest.mark.parametrize("algorithm", solver_names("dfl"))
def test_full_cohort_bit_identical_masked(algorithm):
    part = ParticipationSpec(mode="fraction", p=0.5, seed=7)
    kw = dict(m=M, K=2, algorithm=algorithm, topology="exp", lr=0.05,
              participation=part)
    sd, hd = simulate(loss_fn, None, make_params(), DFLConfig(**kw),
                      make_sampler(M), rounds=4, seed=1)
    sv, hv = simulate(loss_fn, None, make_params(),
                      DFLConfig(n_virtual=M, **kw),
                      make_sampler(M), rounds=4, seed=1)
    _tree_equal(sd.params, sv.params)
    _tree_equal(sd.solver, sv.solver)
    _tree_equal(sd.comm, sv.comm)
    assert hd["loss"] == hv["loss"]
    assert hd["participation"] == hv["participation"]


def test_full_cohort_bit_identical_with_stateful_codec():
    kw = dict(m=M, K=2, topology="ring", lr=0.05, codec="fp8")
    sd, hd = simulate(loss_fn, None, make_params(), DFLConfig(**kw),
                      make_sampler(M), rounds=4, seed=1)
    sv, hv = simulate(loss_fn, None, make_params(),
                      DFLConfig(n_virtual=M, **kw),
                      make_sampler(M), rounds=4, seed=1)
    _tree_equal(sd.params, sv.params)
    _tree_equal(sd.comm, sv.comm)
    assert hd["loss"] == hv["loss"]


# ---------------------------------------------------------------------------
# ClientStore gather/scatter
# ---------------------------------------------------------------------------

def test_gather_scatter_roundtrip_identity():
    cfg = DFLConfig(m=4, n_virtual=20, topology="ring")
    store = ClientStore(make_params(), cfg, seed=0)
    ids = np.array([3, 7, 11, 19])
    st = store.gather(ids)
    assert st.params["w"].shape == (4, 3, 2)
    # scatter the untouched gather back: a later gather must reproduce it
    store.scatter(ids, st)
    assert store.touched == 4
    st2 = store.gather(ids)
    _tree_equal((st.params, st.solver, st.comm),
                (st2.params, st2.solver, st2.comm))
    np.testing.assert_array_equal(np.asarray(st.rng), np.asarray(st2.rng))
    # untouched clients still serve the template row
    st3 = store.gather(np.array([0, 1, 2, 3]))
    np.testing.assert_array_equal(np.asarray(st3.params["w"][0]),
                                  np.zeros((3, 2), np.float32))


def test_store_scatter_keep_mask_skips_rows():
    cfg = DFLConfig(m=4, n_virtual=10, topology="ring")
    store = ClientStore(make_params(), cfg, seed=0)
    ids = np.array([0, 1, 2, 3])
    st = store.gather(ids)
    st = dataclasses.replace(
        st, params=jax.tree.map(lambda x: x + 1.0, st.params))
    store.scatter(ids, st, keep=np.array([True, False, True, False]))
    assert store.touched == 2
    back = store.gather(ids)
    got = np.asarray(back.params["b"])
    np.testing.assert_array_equal(got[0], np.ones(2, np.float32))
    np.testing.assert_array_equal(got[1], np.zeros(2, np.float32))


def test_store_rng_matches_dense_init():
    from repro.core.dfl import init_state
    cfg = DFLConfig(m=5, n_virtual=5, topology="ring")
    store = ClientStore(make_params(), cfg, seed=3)
    dense = init_state(make_params(), cfg, seed=3)
    st = store.gather(np.arange(5))
    np.testing.assert_array_equal(np.asarray(st.rng), np.asarray(dense.rng))


def test_store_rejects_missing_population():
    with pytest.raises(ValueError):
        ClientStore(make_params(), DFLConfig(m=4, topology="ring"), seed=0)


# ---------------------------------------------------------------------------
# cohort sampling
# ---------------------------------------------------------------------------

def test_cohort_ids_deterministic_and_sorted():
    a = cohort_ids(1000, 32, seed=5, t=17)
    b = cohort_ids(1000, 32, seed=5, t=17)
    np.testing.assert_array_equal(a, b)
    assert (np.diff(a) > 0).all() and len(np.unique(a)) == 32
    assert a.min() >= 0 and a.max() < 1000
    # different round / different seed -> different draws
    assert not np.array_equal(a, cohort_ids(1000, 32, seed=5, t=18))
    assert not np.array_equal(a, cohort_ids(1000, 32, seed=6, t=17))
    # full cohort is the identity permutation (the bit-identity path)
    np.testing.assert_array_equal(cohort_ids(8, 8, seed=0, t=3), np.arange(8))
    with pytest.raises(ValueError):
        cohort_ids(10, 11, seed=0, t=0)


def test_virtual_run_deterministic_across_processes():
    """Same seed -> identical history; different seed -> different cohorts."""
    kw = dict(m=4, K=2, topology="ring", lr=0.05, n_virtual=30)
    _, h1 = simulate(loss_fn, None, make_params(), DFLConfig(**kw),
                     cohort_sampler(), rounds=5, seed=9)
    _, h2 = simulate(loss_fn, None, make_params(), DFLConfig(**kw),
                     cohort_sampler(), rounds=5, seed=9)
    assert h1["loss"] == h2["loss"]
    assert h1["store_touched"] == h2["store_touched"]
    _, h3 = simulate(loss_fn, None, make_params(), DFLConfig(**kw),
                     cohort_sampler(), rounds=5, seed=10)
    assert h1["loss"] != h3["loss"]


def test_virtual_device_state_bounded_by_cohort():
    """The jitted round only ever sees (m, ...) arrays regardless of
    n_virtual; the population lives host-side in the store."""
    cfg = DFLConfig(m=4, K=1, topology="ring", lr=0.05, n_virtual=500)
    state, hist = simulate_virtual(loss_fn, None, make_params(), cfg,
                                   cohort_sampler(), rounds=6, seed=0)
    assert state.params["w"].shape[0] == cfg.m
    assert hist["store_touched"][-1] <= 6 * cfg.m
    assert hist["store_touched"] == sorted(hist["store_touched"])


def test_virtual_async_ticks():
    cfg = DFLConfig(m=4, K=2, topology="ring", lr=0.05, n_virtual=20,
                    execution="async", tick_s=0.5, network="lognormal")
    state, hist = simulate(loss_fn, None, make_params(), cfg,
                           cohort_sampler(), rounds=5, seed=3)
    assert "ticked" in hist and len(hist["ticked"]) == 5
    assert all(0.0 <= f <= 1.0 for f in hist["ticked"])
    assert state.params["w"].shape[0] == cfg.m
    # wire bytes only count clients that actually ran
    assert all(b >= 0 for b in hist["wire_bytes"])


def test_virtual_requires_population_at_least_cohort():
    with pytest.raises(ValueError):
        DFLConfig(m=8, n_virtual=4, topology="ring")
