"""Network cost-model layer: presets, timing algebra, the deadline
participation mode, and the docs surfaces that describe them."""
import numpy as np
import pytest

from repro.core import (DFLConfig, NetworkModel, ParticipationSpec,
                        make_gossip, make_network, register_network,
                        simulate)
from repro.core.network import NETWORKS, network_names
from repro.core.participation import (participation_schedule,
                                      round_participation)


def _toy_problem(m=8, K=3, seed=0):
    import jax.numpy as jnp

    def loss_fn(p, batch, rng):
        pred = batch["x"] @ p["w"]
        return jnp.mean((pred - batch["y"]) ** 2)

    rng = np.random.default_rng(seed)
    params = {"w": jnp.asarray(rng.normal(size=(6, 1)), jnp.float32)}

    def sampler(t):
        r = np.random.default_rng((seed, t))
        x = r.normal(size=(m, K, 16, 6)).astype(np.float32)
        y = x.sum(-1, keepdims=True).astype(np.float32)
        return {"x": jnp.asarray(x), "y": jnp.asarray(y)}

    return loss_fn, params, sampler


# ---------------------------------------------------------------------------
# NetworkModel construction and algebra
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("preset", NETWORKS)
def test_presets_build_and_are_deterministic(preset):
    a = make_network(preset, 16, seed=3)
    b = make_network(preset, 16, seed=3)
    assert a.m == 16
    np.testing.assert_array_equal(a.bandwidth, b.bandwidth)
    np.testing.assert_array_equal(a.latency, b.latency)
    # per-round jitter is a pure function of (seed, t)
    np.testing.assert_array_equal(a.link_seconds(10_000, 7),
                                  b.link_seconds(10_000, 7))
    # different rounds draw different jitter (jitter > 0 in presets)
    assert not np.array_equal(a.link_seconds(10_000, 7),
                              a.link_seconds(10_000, 8))


def test_network_seed_changes_draws():
    a = make_network("lognormal", 8, seed=0)
    b = make_network("lognormal", 8, seed=1)
    assert not np.array_equal(a.bandwidth, b.bandwidth)


def test_model_validation():
    ones = np.ones((4, 4))
    with pytest.raises(ValueError, match="positive"):
        NetworkModel(name="x", bandwidth=np.zeros((4, 4)), latency=ones)
    with pytest.raises(ValueError, match="shape"):
        NetworkModel(name="x", bandwidth=ones, latency=np.ones((3, 3)))
    with pytest.raises(ValueError, match="unknown network preset"):
        make_network("adsl", 4)
    with pytest.raises(ValueError, match="m="):
        make_network(make_network("uniform", 4), 8)


def test_transfer_times_follow_in_edges():
    m = 6
    net = make_network("uniform", m, seed=0, jitter=0.0)
    ring = make_gossip("ring", m).matrix
    times = net.transfer_times(ring, nbytes=64_000, t=0)
    expected = net.latency[0, 1] + 64_000 / net.bandwidth[0, 1]
    np.testing.assert_allclose(times, expected)
    # masking: a client with no active in-neighbours waits for nothing
    active = np.zeros(m, dtype=bool)
    active[0] = True
    np.testing.assert_array_equal(
        net.transfer_times(ring, 64_000, 0, active=active), np.zeros(m))


def test_more_bytes_cost_strictly_more_time():
    net = make_network("wan-lan", 16, seed=1)
    w = make_gossip("ring", 16).matrix
    t_small = net.round_time(w, 10_000, 3, K=5)
    t_big = net.round_time(w, 100_000, 3, K=5)
    assert t_big > t_small


def test_register_network_preset_roundtrip():
    def builder(m, seed):
        return NetworkModel(name="flat", bandwidth=np.full((m, m), 1e6),
                            latency=np.zeros((m, m)), seed=seed)
    register_network("flat-test", builder, overwrite=True)
    assert "flat-test" in network_names()
    cfg = DFLConfig(m=4, network="flat-test")
    assert cfg.make_network_model(seed=0).name == "flat"


# ---------------------------------------------------------------------------
# Deadline participation
# ---------------------------------------------------------------------------

def test_deadline_mode_masks_slow_clients():
    spec = ParticipationSpec(mode="deadline", deadline=0.1, min_active=0)
    tt = np.array([0.01, 0.2, 0.05, 0.3])
    rp = round_participation(spec, 4, 0, 5, transfer_times=tt)
    np.testing.assert_array_equal(rp.active, [True, False, True, False])
    np.testing.assert_array_equal(rp.steps, [5, 0, 5, 0])


def test_deadline_min_active_keeps_fastest():
    spec = ParticipationSpec(mode="deadline", deadline=0.001, min_active=2)
    tt = np.array([0.4, 0.2, 0.5, 0.3])
    rp = round_participation(spec, 4, 0, 5, transfer_times=tt)
    # nobody makes the deadline; the floor keeps the two fastest
    np.testing.assert_array_equal(rp.active, [False, True, False, True])


def test_deadline_mode_requires_transfer_times():
    spec = ParticipationSpec(mode="deadline", deadline=0.1)
    with pytest.raises(ValueError, match="transfer_times"):
        round_participation(spec, 4, 0, 5)


def test_deadline_spec_validation():
    with pytest.raises(ValueError, match="deadline"):
        ParticipationSpec(mode="deadline")
    with pytest.raises(ValueError, match="network"):
        DFLConfig(m=4, participation=ParticipationSpec(mode="deadline",
                                                       deadline=0.1))


def test_deadline_schedule_deterministic():
    net = make_network("lognormal", 8, seed=5)
    w = make_gossip("ring", 8).matrix
    tt = [net.transfer_times(w, 10_000, t) for t in range(6)]
    spec = ParticipationSpec(mode="deadline", deadline=0.02)
    a = participation_schedule(spec, 8, 6, 5, transfer_times=tt)
    b = participation_schedule(spec, 8, 6, 5, transfer_times=tt)
    for ra, rb in zip(a, b):
        np.testing.assert_array_equal(ra.active, rb.active)
        np.testing.assert_array_equal(ra.steps, rb.steps)


# ---------------------------------------------------------------------------
# End-to-end through simulate
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_deadline_round_bit_identical_to_schedule_mask():
    """A deadline round must run through exactly the machinery of an
    equivalent schedule mask: same active set => bit-identical state."""
    loss_fn, params, sampler = _toy_problem()
    net = make_network("wan-lan", 8, seed=2)
    base = dict(algorithm="dfedadmm", m=8, K=3, topology="ring", lam=0.5)
    rounds = 3

    # 10 ms sits between the wan-lan LAN (~1 ms) and WAN (~20 ms)
    # latencies, so exactly the site-boundary ring clients miss it
    cfg_dl = DFLConfig(**base, network=net,
                       participation=ParticipationSpec(mode="deadline",
                                                       deadline=0.01))
    state_dl, hist_dl = simulate(loss_fn, None, params, cfg_dl, sampler,
                                 rounds=rounds, seed=0)

    # reconstruct the realized masks and replay them as a schedule
    from repro.core import make_codec
    bytes_pc = make_codec(cfg_dl).bytes_per_client(params)
    w = make_gossip("ring", 8).matrix
    sched = []
    for t in range(rounds):
        tt = net.transfer_times(w, bytes_pc, t)
        rp = round_participation(cfg_dl.participation, 8, t, 3,
                                 transfer_times=tt)
        assert 0 < rp.active.sum() < 8      # the mask actually bites
        sched.append(tuple(np.flatnonzero(rp.active).tolist()))

    cfg_sc = DFLConfig(**base, participation=ParticipationSpec(
        mode="schedule", schedule=tuple(sched)))
    state_sc, _ = simulate(loss_fn, None, params, cfg_sc, sampler,
                           rounds=rounds, seed=0)

    np.testing.assert_array_equal(np.asarray(state_dl.params["w"]),
                                  np.asarray(state_sc.params["w"]))
    np.testing.assert_array_equal(
        np.asarray(state_dl.solver["dual"]["w"]),
        np.asarray(state_sc.solver["dual"]["w"]))
    assert hist_dl["participation"][0] < 1.0


@pytest.mark.slow
def test_sim_time_recorded_and_int8_strictly_faster():
    """int8 messages are smaller than identity, so on the same preset
    every round's modeled time must be strictly smaller."""
    loss_fn, params, sampler = _toy_problem()
    base = dict(algorithm="dfedadmm", m=8, K=3, topology="ring",
                network="wan-lan")
    _, h_id = simulate(loss_fn, None, params, DFLConfig(**base), sampler,
                       rounds=3, seed=0)
    _, h_q = simulate(loss_fn, None, params,
                      DFLConfig(**base, codec="int8"), sampler,
                      rounds=3, seed=0)
    assert len(h_id["sim_time"]) == 3
    for a, b in zip(h_q["sim_time"], h_id["sim_time"]):
        assert a < b
    # and the model is deterministic: replaying identity gives the
    # exact same modeled times
    _, h_id2 = simulate(loss_fn, None, params, DFLConfig(**base), sampler,
                        rounds=3, seed=0)
    assert h_id["sim_time"] == h_id2["sim_time"]


@pytest.mark.slow
def test_simulate_cfl_records_sim_time():
    """The CFL simulator shares the history schema: with a network model
    each round records compute + the slowest cohort upload."""
    import jax.numpy as jnp
    from repro.core import CFLConfig, simulate_cfl

    loss_fn, params, _ = _toy_problem()
    cfg = CFLConfig(algorithm="fedavg", m=8, participation=0.5, K=3,
                    network="hub-and-spoke")

    def sampler(t, ids):
        r = np.random.default_rng((1, t))
        x = r.normal(size=(len(ids), 3, 16, 6)).astype(np.float32)
        y = x.sum(-1, keepdims=True).astype(np.float32)
        return {"x": jnp.asarray(x), "y": jnp.asarray(y)}

    _, hist = simulate_cfl(loss_fn, None, params, cfg, sampler,
                           rounds=2, seed=0)
    assert len(hist["sim_time"]) == 2
    assert all(s > 0 for s in hist["sim_time"])


@pytest.mark.slow
def test_simulate_without_network_has_no_sim_time():
    loss_fn, params, sampler = _toy_problem()
    cfg = DFLConfig(algorithm="dfedavg", m=8, K=3, topology="ring")
    _, hist = simulate(loss_fn, None, params, cfg, sampler,
                       rounds=2, seed=0)
    assert "sim_time" not in hist


# ---------------------------------------------------------------------------
# Deadline-mode sim_time pricing (regression)
# ---------------------------------------------------------------------------

def _forced_client_net(K=3):
    """m=4 ring where the deadline decision and the round price diverge:
    client 0's slow in-link (3 -> 0, 0.5 s) makes it miss the deadline,
    and client 3's in-link (2 -> 3, 0.9 s) is the pre-mask critical
    path.  With ``min_active=3`` the floor forces client 0 back in."""
    lat = np.full((4, 4), 0.001)
    lat[0, 3] = 0.5
    lat[3, 2] = 0.9
    return NetworkModel(name="custom", bandwidth=np.full((4, 4), 1e12),
                        latency=lat, jitter=0.0, compute_s=0.002)


def test_deadline_round_time_prices_forced_clients():
    """The round price is the slowest *realized* wait among included
    clients — the min_active-forced client's 0.5 s transfer, not the
    post-mask subgraph's ~1 ms and not the excluded critical path."""
    net = _forced_client_net()
    w = make_gossip("ring", 4).matrix
    transfer = net.transfer_times(w, 24, 0)
    np.testing.assert_allclose(transfer, [0.5, 0.001, 0.001, 0.9])
    active = np.array([True, True, True, False])
    got = net.deadline_round_time(transfer, active, K=3)
    np.testing.assert_allclose(got, 3 * 0.002 + 0.5)
    # and it is neither of the two wrong readings
    assert not np.isclose(got, 3 * 0.002 + 0.001, atol=1e-4)   # post-mask
    assert not np.isclose(got, 3 * 0.002 + 0.9, atol=1e-4)     # pre-mask max


@pytest.mark.slow
def test_simulate_deadline_sim_time_regression():
    """End-to-end pin of the deadline pricing through simulate: the
    forced client's decision-time transfer dominates sim_time."""
    loss_fn, params, sampler = _toy_problem(m=4)
    cfg = DFLConfig(
        algorithm="dfedavg", m=4, K=3, topology="ring",
        network=_forced_client_net(),
        participation=ParticipationSpec(mode="deadline", deadline=0.01,
                                        min_active=3))
    _, hist = simulate(loss_fn, None, params, cfg, sampler,
                       rounds=2, seed=0)
    np.testing.assert_allclose(hist["sim_time"], [3 * 0.002 + 0.5] * 2)
