"""Decode path == full forward logits, for every architecture family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.data.synthetic import make_model_batch
from repro.models import build_model
from repro.models.model import logits_fn

pytestmark = pytest.mark.slow  # jit/subprocess-heavy: excluded from the fast tier



@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_forward(arch):
    cfg = get_smoke_config(arch)
    if cfg.arch_type == "moe":
        # capacity-based token dropping depends on the token count per
        # dispatch (B*S at prefill vs B at decode); equivalence holds in
        # the no-drop regime, so lift the capacity for this test.
        import dataclasses
        cfg = dataclasses.replace(cfg, capacity_factor=64.0)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S, tail = 2, 18, 3
    batch = jax.tree.map(jnp.asarray, make_model_batch(cfg, B, S, seed=5))
    full = logits_fn(params, cfg, batch)          # (B, S_total, V)

    pre = dict(batch)
    off = cfg.prefix_tokens  # vlm: logits include the patch prefix
    if cfg.arch_type == "audio":
        cut = S - tail
        pre["embeds"] = batch["embeds"][:, :cut]
    else:
        ntok = batch["tokens"].shape[1]
        cut = ntok - tail
        pre["tokens"] = batch["tokens"][:, :cut]
    pre.pop("labels", None)

    logits, cache = model.prefill(params, pre, S + 4)
    np.testing.assert_allclose(
        np.asarray(logits, np.float32),
        np.asarray(full[:, off + cut - 1], np.float32), rtol=2e-3, atol=2e-3)

    for i in range(tail):
        step_in = (batch["embeds"][:, cut + i][:, None]
                   if cfg.arch_type == "audio"
                   else batch["tokens"][:, cut + i])
        logits, cache = model.decode_step(params, cache, step_in)
        np.testing.assert_allclose(
            np.asarray(logits, np.float32),
            np.asarray(full[:, off + cut + i], np.float32),
            rtol=2e-3, atol=2e-3)
