"""The dry-run pipeline end-to-end on a small in-CI mesh (subprocess with
forced host devices; smoke-size configs, reduced shapes)."""
import os
import subprocess
import sys

import jax
import pytest

pytestmark = [
    pytest.mark.slow,  # jit/subprocess-heavy: excluded from the fast tier
    # the dry-run mesh needs jax.sharding.AxisType (jax >= 0.5)
    pytest.mark.skipif(not hasattr(jax.sharding, "AxisType"),
                       reason="jax.sharding.AxisType unavailable in this jax"),
]

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
import dataclasses, jax
from jax.sharding import AxisType
from repro.launch import dryrun_lib as dl
from repro.configs import get_smoke_config
from repro.configs import shapes as sh

# reduced shapes so CPU compile stays fast
sh.SHAPES = {
    "train_4k": sh.InputShape("train_4k", 64, 8, "train"),
    "prefill_32k": sh.InputShape("prefill_32k", 128, 4, "prefill"),
    "decode_32k": sh.InputShape("decode_32k", 128, 4, "decode"),
    "long_500k": sh.InputShape("long_500k", 512, 1, "decode"),
}
dl.SHAPES = sh.SHAPES

orig = dl.resolve
def small_resolve(arch_id, variant, multi_pod):
    cfg, par = orig(arch_id, variant, multi_pod)
    cfg = get_smoke_config(arch_id)
    if variant.loss_chunk >= 0:
        cfg = dataclasses.replace(cfg, loss_chunk=variant.loss_chunk)
    par = dataclasses.replace(par, dfl_m=4 if not multi_pod else 2,
                              dfl_k=2, batch_axes=("pod",) if multi_pod else ())
    return cfg, par
dl.resolve = small_resolve

single = jax.make_mesh((4, 2), ("data", "model"),
                       axis_types=(AxisType.Auto,) * 2)
multi = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                      axis_types=(AxisType.Auto,) * 3)

import sys

arch, shape, mesh_kind, variant_name = sys.argv[1:5]
mesh = single if mesh_kind == "single" else multi
variant = dl.DryrunVariant(name=variant_name,
                           mixing="ppermute" if variant_name == "ppermute"
                           else "dense",
                           flash_decode=(variant_name == "flash"),
                           kv_shard="seq" if variant_name == "kv_seq" else "",
                           metrics="light" if variant_name == "optimized"
                           else "full",
                           microbatches=2 if variant_name == "microbatch"
                           else 0,
                           remat=True if variant_name == "optimized"
                           else None)
rec = dl.dryrun_one(arch, shape, multi_pod=(mesh_kind == "multi"),
                    variant=variant, mesh=mesh, save=False)
assert rec["status"] in ("ok", "skipped"), rec
if rec["status"] == "ok":
    assert rec["roofline"]["t_compute_s"] >= 0
    assert rec["cost"].get("flops", 0) > 0
print("DRYRUN_MINI_OK", rec["status"])
"""


def _run(arch, shape, mesh_kind="single", variant="baseline"):
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SCRIPT, arch, shape,
                        mesh_kind, variant], env=env, capture_output=True,
                       text=True, timeout=900)
    assert r.returncode == 0, (r.stdout[-1000:], r.stderr[-3000:])
    assert "DRYRUN_MINI_OK" in r.stdout


@pytest.mark.parametrize("arch", ["llama3-8b", "mixtral-8x7b",
                                  "falcon-mamba-7b", "zamba2-1.2b",
                                  "paligemma-3b"])
def test_train_dryrun_single_pod(arch):
    _run(arch, "train_4k", "single")


@pytest.mark.parametrize("arch", ["llama3-8b", "zamba2-1.2b"])
def test_train_dryrun_multi_pod(arch):
    _run(arch, "train_4k", "multi")


def test_decode_dryrun(mesh_kind="single"):
    _run("gemma3-12b", "decode_32k", mesh_kind)


def test_long_context_dryrun():
    _run("zamba2-1.2b", "long_500k", "single")


def test_long_context_flash_variant():
    _run("gemma3-12b", "long_500k", "single", "flash")


def test_ppermute_variant_lowering():
    _run("llama3-8b", "train_4k", "single", "ppermute")


def test_prefill_dryrun():
    _run("musicgen-large", "prefill_32k", "single")


def test_kv_seq_variant_decode():
    """seq-sharded decode cache (§Perf pair A lever) lowers on the mini
    mesh."""
    _run("gemma3-12b", "decode_32k", "single", "kv_seq")


def test_optimized_variant_train():
    """remat + light-metrics train round (§Perf defaults) lowers."""
    _run("llama3-8b", "train_4k", "single", "optimized")


def test_microbatch_variant_train():
    """grad-accumulation inner step (§Perf pair C it.4) lowers."""
    _run("mixtral-8x7b", "train_4k", "single", "microbatch")
