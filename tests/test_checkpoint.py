"""Checkpoint round-trips + manifest validation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore_pytree, save_pytree


def _tree():
    return {"layers": {"w": jnp.arange(12.0).reshape(3, 4),
                       "b": jnp.ones(4, jnp.bfloat16)},
            "head": jnp.full((2, 2), 3, jnp.int32)}


def test_roundtrip(tmp_path):
    t = _tree()
    save_pytree(str(tmp_path), 7, t)
    r = restore_pytree(str(tmp_path), 7, jax.tree.map(jnp.zeros_like, t))
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(r)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_latest_step(tmp_path):
    assert latest_step(str(tmp_path)) is None
    for s in (1, 5, 3):
        save_pytree(str(tmp_path), s, _tree())
    assert latest_step(str(tmp_path)) == 5


def test_shape_mismatch_raises(tmp_path):
    save_pytree(str(tmp_path), 1, _tree())
    bad = _tree()
    bad["head"] = jnp.zeros((3, 3), jnp.int32)
    with pytest.raises(ValueError):
        restore_pytree(str(tmp_path), 1, bad)


def test_structure_mismatch_raises(tmp_path):
    save_pytree(str(tmp_path), 1, _tree())
    bad = {"other": jnp.zeros(3)}
    with pytest.raises(ValueError):
        restore_pytree(str(tmp_path), 1, bad)


def test_overwrite_same_step(tmp_path):
    t = _tree()
    save_pytree(str(tmp_path), 2, t)
    t2 = jax.tree.map(lambda x: x + 1, t)
    save_pytree(str(tmp_path), 2, t2)
    r = restore_pytree(str(tmp_path), 2, jax.tree.map(jnp.zeros_like, t))
    np.testing.assert_allclose(np.asarray(r["layers"]["w"]),
                               np.asarray(t2["layers"]["w"]))
