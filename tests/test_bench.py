"""Perf-regression harness: BENCH_*.json determinism, timing-stat
contracts, and the ``tools/bench_compare.py`` CI gate (pass, regression,
missing metric, new metric, noise guard)."""
import importlib.util
import json
import os
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")
sys.path.insert(0, os.path.abspath(ROOT))          # "benchmarks" package

_spec = importlib.util.spec_from_file_location(
    "bench_compare", os.path.join(ROOT, "tools", "bench_compare.py"))
bench_compare = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(bench_compare)


def _doc(rows, suite="kernels"):
    return {"schema": 1, "suite": suite, "quick": True, "rows": rows}


def _row(name, us, spread=None, derived="oracle"):
    return {"name": name, "us_per_call": us, "spread_us": spread,
            "derived": derived}


# ---------------------------------------------------------------------------
# tools/bench_compare.py semantics
# ---------------------------------------------------------------------------

def test_compare_passes_within_threshold():
    base = _doc([_row("k/a", 100.0), _row("k/b", 50.0)])
    cur = _doc([_row("k/a", 180.0), _row("k/b", 55.0)])
    res = bench_compare.compare(base, cur, threshold=2.0)
    assert not res["failed"] and len(res["ok"]) == 2
    assert not res["regressions"] and not res["missing"] and not res["new"]


def test_compare_fails_on_injected_slowdown():
    base = _doc([_row("k/a", 100.0), _row("k/b", 50.0)])
    cur = _doc([_row("k/a", 100.0), _row("k/b", 500.0)])   # 10x slowdown
    res = bench_compare.compare(base, cur, threshold=2.0)
    assert res["failed"]
    assert [r["name"] for r in res["regressions"]] == ["k/b"]
    assert res["regressions"][0]["ratio"] == pytest.approx(10.0)


def test_compare_missing_metric_fails_unless_allowed():
    base = _doc([_row("k/a", 100.0), _row("k/gone", 10.0)])
    cur = _doc([_row("k/a", 100.0)])
    res = bench_compare.compare(base, cur)
    assert res["failed"] and [r["name"] for r in res["missing"]] == ["k/gone"]
    res = bench_compare.compare(base, cur, allow_missing=True)
    assert not res["failed"]


def test_compare_new_metric_passes():
    base = _doc([_row("k/a", 100.0)])
    cur = _doc([_row("k/a", 100.0), _row("k/new", 9999.0)])
    res = bench_compare.compare(base, cur)
    assert not res["failed"]
    assert [r["name"] for r in res["new"]] == ["k/new"]


def test_compare_spread_noise_guard():
    """A noisy metric (large baseline IQR) is allowed to exceed the
    relative threshold by spread_mult * spread before it regresses."""
    base = _doc([_row("k/noisy", 10.0, spread=50.0)])
    cur = _doc([_row("k/noisy", 100.0)])        # 10x, but within 4 IQRs
    res = bench_compare.compare(base, cur, threshold=2.0, spread_mult=4.0)
    assert not res["failed"]
    cur = _doc([_row("k/noisy", 300.0)])        # beyond both guards
    res = bench_compare.compare(base, cur, threshold=2.0, spread_mult=4.0)
    assert res["failed"]


def test_compare_per_metric_threshold_override():
    base = _doc([_row("k/hot", 100.0), _row("k/cold", 100.0)])
    cur = _doc([_row("k/hot", 140.0), _row("k/cold", 140.0)])
    res = bench_compare.compare(base, cur, threshold=2.0,
                                metric_thresholds={"k/hot": 1.2})
    assert [r["name"] for r in res["regressions"]] == ["k/hot"]


def test_compare_cli_exit_codes(tmp_path, capsys):
    base = tmp_path / "base.json"
    cur = tmp_path / "cur.json"
    base.write_text(json.dumps(_doc([_row("k/a", 100.0)])))
    cur.write_text(json.dumps(_doc([_row("k/a", 120.0)])))
    assert bench_compare.main([str(base), str(cur)]) == 0
    cur.write_text(json.dumps(_doc([_row("k/a", 9000.0)])))
    assert bench_compare.main([str(base), str(cur)]) == 1
    assert "REGRESSION" in capsys.readouterr().out
    cur.write_text("{}")                        # schema error
    assert bench_compare.main([str(base), str(cur)]) == 2
    assert bench_compare.main([str(base), str(tmp_path / "nope.json")]) == 2


# ---------------------------------------------------------------------------
# benchmarks.common timing contracts
# ---------------------------------------------------------------------------

def test_time_stats_contract():
    from benchmarks import common
    st = common.time_stats(lambda: sum(range(100)), warmup=1, iters=5)
    assert st["iters"] == 5 and st["warmup"] == 1
    assert st["min_us"] <= st["median_us"]
    assert st["spread_us"] >= 0.0
    assert common.time_fn(lambda: None, warmup=1, iters=3) >= 0.0
    for bad in (dict(warmup=0), dict(iters=0)):
        with pytest.raises(ValueError):
            common.time_stats(lambda: None, **bad)


def test_steady_state_us_drops_compile_round():
    from benchmarks import common
    med, iqr = common.steady_state_us({"wall_us": [1e6, 10.0, 12.0, 11.0]})
    assert med == 11.0 and iqr <= 2.0            # round 0 excluded
    med, _ = common.steady_state_us({"wall_us": [42.0]})
    assert med == 42.0                           # single round: keep it
    import math
    med, iqr = common.steady_state_us({})
    assert math.isnan(med) and iqr == 0.0


def test_simulate_history_carries_wall_us():
    import jax.numpy as jnp
    import numpy as np
    from repro.core import DFLConfig, simulate

    rng = np.random.default_rng(0)
    params = {"w": jnp.asarray(rng.normal(size=(5, 2)) / 2, jnp.float32)}

    def loss(p, batch, r):
        return jnp.mean((batch["x"] @ p["w"] - batch["y"]) ** 2)

    def sampler(t):
        r = np.random.default_rng(t)
        return {"x": jnp.asarray(r.normal(size=(4, 2, 8, 5)), jnp.float32),
                "y": jnp.asarray(r.normal(size=(4, 2, 8, 2)), jnp.float32)}

    cfg = DFLConfig(algorithm="dfedavg", m=4, K=2, topology="ring")
    _, hist = simulate(loss, None, params, cfg, sampler, rounds=3)
    assert len(hist["wall_us"]) == 3
    assert all(t > 0 for t in hist["wall_us"])


# ---------------------------------------------------------------------------
# benchmarks.run --dump-json determinism
# ---------------------------------------------------------------------------

TIMING_FIELDS = ("us_per_call", "spread_us")


def _strip_timing(doc):
    return {**doc, "rows": [{k: v for k, v in r.items()
                             if k not in TIMING_FIELDS}
                            for r in doc["rows"]]}


def test_dump_json_deterministic_across_runs(tmp_path, capsys):
    """Two ``run.py --suite kernels --quick --dump-json`` invocations
    agree on every non-timing field — names, derived metrics (max_err),
    schema, suite, quick — so the CI artifact diffs clean."""
    from benchmarks import run as brun
    docs = []
    for d in ("a", "b"):
        out = tmp_path / d
        assert brun.main(["--suite", "kernels", "--quick",
                          "--dump-json", str(out)]) == 0
        docs.append(json.loads((out / "BENCH_kernels.json").read_text()))
    capsys.readouterr()
    a, b = docs
    assert a["schema"] == brun.BENCH_SCHEMA_VERSION
    assert a["suite"] == "kernels" and a["quick"] is True
    assert [r["name"] for r in a["rows"]] == [r["name"] for r in b["rows"]]
    assert _strip_timing(a) == _strip_timing(b)
    # timing fields exist and are positive (but are allowed to differ)
    assert all(r["us_per_call"] > 0 for r in a["rows"])


def test_dump_json_round_trips_through_compare(tmp_path, capsys):
    """A fresh run compared against itself passes the gate; the same run
    with a deliberately injected slowdown fails it."""
    from benchmarks import run as brun
    out = tmp_path / "run"
    assert brun.main(["--suite", "kernels", "--quick",
                      "--dump-json", str(out)]) == 0
    capsys.readouterr()
    path = out / "BENCH_kernels.json"
    doc = json.loads(path.read_text())
    assert bench_compare.compare(doc, doc)["failed"] is False
    slow = {**doc, "rows": [{**r, "us_per_call": r["us_per_call"] * 100}
                            for r in doc["rows"]]}
    res = bench_compare.compare(doc, slow, threshold=3.0)
    assert res["failed"] and len(res["regressions"]) == len(doc["rows"])
