"""Centralized baselines (FedAvg / FedSAM / FedPD) sanity."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CFLConfig, init_cfl_state, make_cfl_round, simulate_cfl
from tests.test_fl_system import _loss, _mlp_init, _acc, _task


def _run_cfl(algo, rounds=20, alpha=0.3, seed=0):
    task = _task()
    m = 20
    parts = task.partition(m, alpha, seed=seed)
    sampler0 = task.client_sampler(parts, batch=32, K=5, seed=seed)

    def sampler(t, ids):
        b = sampler0(t)
        return {"x": jnp.asarray(b["x"][ids]), "y": jnp.asarray(b["y"][ids])}

    cfg = CFLConfig(algorithm=algo, m=m, participation=0.25, K=5, lr=0.1)
    params = _mlp_init(task.dim, task.n_classes)
    state, hist = simulate_cfl(_loss, None, params, cfg, sampler,
                               rounds=rounds, seed=seed)
    return _acc(state.global_params, task), hist


@pytest.mark.parametrize("algo", ["fedavg", "fedsam", "fedpd"])
def test_cfl_learns(algo):
    acc, hist = _run_cfl(algo)
    assert acc > 0.55, (algo, acc)
    assert np.isfinite(hist["loss"]).all()


def test_fedpd_dual_state_updates():
    task = _task()
    cfg = CFLConfig(algorithm="fedpd", m=4, participation=1.0, K=3)
    params = _mlp_init(task.dim, task.n_classes)
    state = init_cfl_state(params, cfg)
    round_fn = make_cfl_round(_loss, cfg)
    ids = jnp.arange(4)
    batch = {"x": jnp.asarray(task.x_train[:4 * 3 * 8].reshape(4, 3, 8, 16)),
             "y": jnp.asarray(task.y_train[:4 * 3 * 8].reshape(4, 3, 8))}
    new_state, metrics = round_fn(state, ids, batch)
    dn = float(sum(jnp.sum(jnp.abs(x)) for x in
                   (new_state.solver["dual"]["w1"],
                    new_state.solver["dual"]["w2"])))
    assert dn > 0.0
    assert np.isfinite(float(metrics["loss"]))
