"""Microbatch gradient accumulation == full-batch inner step (exact for
DFedADMM; the f32 accumulator makes the split *at least* as accurate)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DFLConfig, make_gossip, make_train_round
from repro.core.dfl import init_state


def _setup(microbatches, m=4, K=2, b=8, dim=6):
    cfg = DFLConfig(algorithm="dfedadmm", m=m, K=K, topology="ring",
                    transport="dense", microbatches=microbatches)
    spec = make_gossip("ring", m)

    def loss_fn(p, batch, rng):
        pred = batch["x"] @ p["w"]
        return jnp.mean((pred - batch["y"]) ** 2)

    params = {"w": jnp.ones((dim, 3), jnp.float32)}
    state = init_state(params, cfg)
    rng = np.random.default_rng(0)
    batches = {"x": jnp.asarray(rng.normal(size=(m, K, b, dim)), jnp.float32),
               "y": jnp.asarray(rng.normal(size=(m, K, b, 3)), jnp.float32)}
    w = jnp.asarray(spec.matrix, jnp.float32)
    rf = jax.jit(make_train_round(loss_fn, cfg, spec=spec))
    return rf, state, batches, w


@pytest.mark.parametrize("n", [2, 4])
def test_microbatch_matches_full_batch(n):
    rf1, s1, b1, w = _setup(1)
    rfn, sn, bn, _ = _setup(n)
    out1, m1 = rf1(s1, b1, w)
    outn, mn = rfn(sn, bn, w)
    for a, c in zip(jax.tree.leaves(out1.params), jax.tree.leaves(outn.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                   rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(float(m1["loss"]), float(mn["loss"]),
                               rtol=1e-5)


def test_microbatch_dual_matches():
    rf1, s1, b1, w = _setup(1)
    rf2, s2, b2, _ = _setup(2)
    out1, _ = rf1(s1, b1, w)
    out2, _ = rf2(s2, b2, w)
    for a, c in zip(jax.tree.leaves(out1.solver["dual"]),
                    jax.tree.leaves(out2.solver["dual"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                   rtol=1e-5, atol=1e-6)
