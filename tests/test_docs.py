"""The docs tree stays truthful: internal markdown links resolve and the
worked examples in docs/extending.md execute against the current API
(the same checks the CI docs job runs)."""
import doctest
import importlib.util
import os

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _load_check_links():
    path = os.path.join(ROOT, "tools", "check_links.py")
    spec = importlib.util.spec_from_file_location("check_links", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_markdown_links_resolve():
    mod = _load_check_links()
    errors = []
    for f in mod.doc_files(ROOT):
        errors.extend(mod.check_file(f, ROOT))
    assert not errors, "broken markdown links:\n" + "\n".join(errors)


def test_docs_surfaces_exist():
    for rel in ("README.md", "docs/architecture.md", "docs/extending.md",
                "docs/benchmarks.md"):
        assert os.path.exists(os.path.join(ROOT, rel)), f"missing {rel}"


def test_extending_doctests_pass():
    result = doctest.testfile(
        os.path.join(ROOT, "docs", "extending.md"), module_relative=False)
    assert result.attempted > 0
    assert result.failed == 0
