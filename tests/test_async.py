"""Async execution engine (repro.core.async_engine): bit-identity
reduction to the synchronous round, determinism, scheduler/staleness
invariants, and composition with every transport x codec pair."""
import numpy as np
import pytest

from repro.core import (DFLConfig, NetworkModel, ParticipationSpec,
                        make_codec, simulate, solver_names)
from repro.core.async_engine import AsyncScheduler, effective_matrix
from repro.core.gossip import (as_column_stochastic, make_gossip,
                               mask_and_renormalize,
                               mask_and_renormalize_columns,
                               time_varying_specs)


def _flat_net(m, compute_s=0.002):
    """Uniform zero-latency zero-jitter network: every client's round
    time is K*compute_s + eps, so ``tick_s=1.0`` puts every client in
    every tick — the async schedule degenerates to the sync rounds."""
    return NetworkModel(name="flat", bandwidth=np.full((m, m), 1e12),
                        latency=np.zeros((m, m)), jitter=0.0,
                        compute_s=compute_s)


def _toy_problem(m=8, K=3, seed=0):
    import jax.numpy as jnp

    def loss_fn(p, batch, rng):
        pred = batch["x"] @ p["w"]
        return jnp.mean((pred - batch["y"]) ** 2)

    rng = np.random.default_rng(seed)
    params = {"w": jnp.asarray(rng.normal(size=(6, 1)), jnp.float32)}

    def sampler(t):
        r = np.random.default_rng((seed, t))
        x = r.normal(size=(m, K, 16, 6)).astype(np.float32)
        y = x.sum(-1, keepdims=True).astype(np.float32)
        return {"x": jnp.asarray(x), "y": jnp.asarray(y)}

    return loss_fn, params, sampler


def _bit_identity_case(algo, rounds=4, m=8, K=3):
    loss_fn, params, sampler = _toy_problem(m=m, K=K)
    base = dict(algorithm=algo, m=m, K=K, topology="ring",
                network=_flat_net(m))
    st_s, h_s = simulate(loss_fn, None, params, DFLConfig(**base),
                         sampler, rounds=rounds, seed=0)
    st_a, h_a = simulate(loss_fn, None, params,
                         DFLConfig(**base, execution="async", tick_s=1.0,
                                   max_staleness=2),
                         sampler, rounds=rounds, seed=0)
    assert h_s["loss"] == h_a["loss"]          # bitwise, every round
    assert h_a["ticked"] == [1.0] * rounds
    assert h_a["staleness"] == [0] * rounds
    np.testing.assert_array_equal(np.asarray(st_s.params["w"]),
                                  np.asarray(st_a.params["w"]))
    np.testing.assert_allclose(h_a["sim_time"], h_s["sim_time"],
                               rtol=1e-9)


# ---------------------------------------------------------------------------
# Sync reduction + determinism
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo", ["dfedadmm", "dfedavg"])
def test_async_reduces_to_sync_bitwise(algo):
    """Zero latency + tick_s >= round time: the async tick IS the sync
    round — history["loss"] matches bit for bit."""
    _bit_identity_case(algo)


@pytest.mark.slow
@pytest.mark.parametrize("algo", sorted(solver_names("dfl")))
def test_async_reduces_to_sync_bitwise_all_solvers(algo):
    """The acceptance pin: the reduction holds for every registered DFL
    solver (the shared make_local_phase carries the whole zoo)."""
    _bit_identity_case(algo, rounds=3)


def test_async_determinism_under_fixed_seed():
    loss_fn, params, sampler = _toy_problem()
    cfg = DFLConfig(algorithm="dfedadmm", m=8, K=3, topology="ring",
                    network="wan-lan", execution="async", tick_s=0.02,
                    max_staleness=3)
    _, h1 = simulate(loss_fn, None, params, cfg, sampler, rounds=6, seed=0)
    _, h2 = simulate(loss_fn, None, params, cfg, sampler, rounds=6, seed=0)
    for key in ("loss", "sim_time", "staleness", "ticked", "wire_bytes"):
        assert h1[key] == h2[key]
    assert any(f < 1.0 for f in h1["ticked"])   # genuinely async schedule


def test_async_empty_ticks_freeze_state():
    """tick_s below the round time: the first window has no completions
    — no jitted call runs, the row records NaN loss / zero time."""
    loss_fn, params, sampler = _toy_problem()
    cfg = DFLConfig(algorithm="dfedavg", m=8, K=3, topology="ring",
                    network=_flat_net(8), execution="async", tick_s=0.004,
                    max_staleness=4)
    _, h = simulate(loss_fn, None, params, cfg, sampler, rounds=4, seed=0)
    assert np.isnan(h["loss"][0]) and h["ticked"][0] == 0.0
    assert h["sim_time"][0] == 0.0 and h["wire_bytes"][0] == 0
    assert h["ticked"][1] == 1.0 and np.isfinite(h["loss"][1])


def test_async_wire_bytes_counts_only_ticking_clients():
    """Regression pin: under execution="async" the per-tick
    history["wire_bytes"] is bytes_per_client x (number of clients that
    ticked in that window) — the uplink of the publishers only, never
    bytes_per_client x m, and exactly zero on an empty tick."""
    loss_fn, params, sampler = _toy_problem()
    cfg = DFLConfig(algorithm="dfedadmm", m=8, K=3, topology="ring",
                    network="wan-lan", execution="async", tick_s=0.02,
                    max_staleness=3)
    _, h = simulate(loss_fn, None, params, cfg, sampler, rounds=8, seed=0)
    bytes_pc = make_codec(cfg).bytes_per_client(params)
    assert any(0.0 < f < 1.0 for f in h["ticked"])   # partial ticks occur
    for frac, wb in zip(h["ticked"], h["wire_bytes"]):
        n_ticking = round(frac * cfg.m)
        assert wb == bytes_pc * n_ticking
        if n_ticking == 0:
            assert wb == 0
        assert wb < bytes_pc * cfg.m or n_ticking == cfg.m


def test_async_config_validation():
    with pytest.raises(ValueError, match="execution"):
        DFLConfig(m=4, execution="eventual")
    with pytest.raises(ValueError, match="network"):
        DFLConfig(m=4, execution="async", tick_s=0.1)
    with pytest.raises(ValueError, match="tick_s"):
        DFLConfig(m=4, execution="async", network="uniform")
    with pytest.raises(ValueError, match="max_staleness"):
        DFLConfig(m=4, execution="async", network="uniform", tick_s=0.1,
                  max_staleness=-1)
    with pytest.raises(ValueError, match="deadline"):
        DFLConfig(m=4, execution="async", network="uniform", tick_s=0.1,
                  participation=ParticipationSpec(mode="deadline",
                                                  deadline=0.05))


# ---------------------------------------------------------------------------
# Scheduler + effective matrix invariants (host-side, no jit)
# ---------------------------------------------------------------------------

def test_scheduler_clocks_and_staleness():
    m = 6
    from repro.core import make_network
    net = make_network("lognormal", m, seed=3)
    specs = time_varying_specs("ring", m, 12)
    cfg = DFLConfig(algorithm="dfedadmm", m=m, K=3, topology="ring",
                    network=net, execution="async", tick_s=0.02,
                    max_staleness=2)
    sched = AsyncScheduler(cfg, net, specs, bytes_per_client=10_000)
    prev_clock = sched.clock.copy()
    cum = 0.0
    for t in range(12):
        ev = sched.step(t)
        assert (sched.clock >= prev_clock).all()     # non-decreasing
        prev_clock = sched.clock.copy()
        assert ev.staleness <= cfg.max_staleness
        assert (ev.ages[ev.fresh] <= cfg.max_staleness).all()
        assert (ev.ages[ev.active] == 0).all()
        assert (ev.steps[~ev.active] == 0).all()
        assert ev.sim_dt >= 0.0
        cum += ev.sim_dt
        # applied events all lie inside the windows seen so far
        assert cum <= (t + 1) * cfg.tick_s + 1e-12


def test_scheduler_composes_with_sampling_participation():
    """A sampled-out client defers its completion instead of losing it:
    its round count never regresses and it eventually ticks."""
    m = 6
    from repro.core import make_network
    net = make_network("uniform", m, seed=0, jitter=0.0)
    specs = time_varying_specs("ring", m, 10)
    cfg = DFLConfig(algorithm="dfedavg", m=m, K=3, topology="ring",
                    network=net, execution="async", tick_s=1.0,
                    max_staleness=8,
                    participation=ParticipationSpec(mode="uniform", p=0.5,
                                                    seed=1))
    sched = AsyncScheduler(cfg, net, specs, bytes_per_client=100)
    prev = sched.rounds_done.copy()
    for t in range(10):
        ev = sched.step(t)
        assert (sched.rounds_done >= prev).all()
        prev = sched.rounds_done.copy()
        assert (ev.active <= (sched.done > 0)).all()
    assert (sched.rounds_done >= 1).all()            # nobody starves


def test_effective_matrix_reduces_to_masked_plan():
    """With receiving == fresh the effective matrix IS the participation
    machinery's masked plan (Definition 1 on the active subgraph)."""
    m = 8
    w = make_gossip("exp", m).matrix
    active = np.array([1, 0, 1, 1, 0, 1, 1, 1], dtype=bool)
    np.testing.assert_array_equal(effective_matrix(w, active, active),
                                  mask_and_renormalize(w, active))
    p = as_column_stochastic(make_gossip("dring", m).matrix)
    np.testing.assert_array_equal(
        effective_matrix(p, active, active, column=True),
        mask_and_renormalize_columns(p, active))


def test_effective_matrix_asymmetric_masks():
    """Stale senders are masked with the lost mass on the receiver's
    diagonal: rows stay stochastic, non-receiving rows stay identity."""
    m = 6
    w = make_gossip("ring", m).matrix
    receiving = np.array([1, 1, 0, 1, 1, 0], dtype=bool)
    fresh = np.array([1, 0, 1, 1, 0, 1], dtype=bool)
    wm = effective_matrix(w, receiving, fresh)
    np.testing.assert_allclose(wm.sum(axis=1), 1.0, atol=1e-12)
    assert (wm >= 0.0).all()
    for i in np.flatnonzero(~receiving):
        expect = np.zeros(m)
        expect[i] = 1.0
        np.testing.assert_array_equal(wm[i], expect)
    # a stale sender contributes to nobody but itself
    for j in np.flatnonzero(~fresh):
        off = np.delete(wm[:, j], j)
        assert (off == 0.0).all()


# ---------------------------------------------------------------------------
# Composition with the communication layer
# ---------------------------------------------------------------------------

_PAIRS = [
    ("dense", "identity", "ring"),
    ("pushsum", "identity", "dring"),
] + [
    pytest.param(*p, marks=pytest.mark.slow) for p in [
        ("dense", "int8", "ring"),
        ("dense", "topk", "ring"),
        ("dense", "randk", "ring"),
        ("ppermute", "identity", "ring"),
        ("ppermute", "int8", "ring"),
        ("ppermute", "topk", "ring"),
        ("ppermute", "randk", "ring"),
        ("pushsum", "int8", "dring"),
        ("pushsum", "topk", "dring"),
        ("pushsum", "randk", "dring"),
    ]
]


@pytest.mark.parametrize("transport,codec,topology", _PAIRS)
def test_async_comm_composition(transport, codec, topology):
    """Every (transport, codec) pair runs under async ticks with the
    wire/state telemetry consistent: wire_bytes counts the tick's
    publishers, residuals stay finite, push-sum mass stays conserved."""
    import jax.numpy as jnp

    m, ticks = 8, 6
    loss_fn, params, sampler = _toy_problem(m=m)
    cfg = DFLConfig(algorithm="dfedadmm", m=m, K=3, topology=topology,
                    transport=transport, codec=codec, codec_k=4,
                    network="wan-lan", execution="async", tick_s=0.02,
                    max_staleness=3)
    state, h = simulate(loss_fn, None, params, cfg, sampler,
                        rounds=ticks, seed=0)
    bytes_pc = make_codec(cfg).bytes_per_client(params)
    assert len(h["wire_bytes"]) == ticks
    for frac, wb, stale in zip(h["ticked"], h["wire_bytes"],
                               h["staleness"]):
        assert wb == bytes_pc * round(frac * m)
        assert 0 <= stale <= cfg.max_staleness
    assert any(f < 1.0 for f in h["ticked"])     # schedule actually async
    if make_codec(cfg).stateful:
        resid = state.comm["residual"]["w"]
        assert bool(jnp.isfinite(resid).all())
    if transport == "pushsum":
        pi = np.asarray(state.comm["ps_weight"])
        assert (pi > 0).all()
        assert np.isclose(pi.sum(), 1.0, atol=1e-5)
