"""Hypothesis property tests on system invariants.

``hypothesis`` is an optional dependency: the whole module is skipped
(not a collection error) when it is absent, so the tier-1 run
``PYTHONPATH=src python -m pytest -x -q`` works on a clean environment.
"""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
import hypothesis.strategies as st  # noqa: E402
from hypothesis import given, settings  # noqa: E402,F401

from repro.core import admm, gossip, mixing  # noqa: E402
from repro.data.federated import dirichlet_partition, iid_partition  # noqa: E402

hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=25,
    suppress_health_check=list(hypothesis.HealthCheck))
hypothesis.settings.load_profile("ci")


@given(m=st.integers(2, 40),
       topo=st.sampled_from(["ring", "exp", "full"]),
       weights=st.sampled_from(["metropolis", "uniform"]))
def test_gossip_matrix_always_valid(m, topo, weights):
    spec = gossip.make_gossip(topo, m, weights=weights)
    gossip.validate_gossip_matrix(spec.matrix)
    assert 0.0 <= spec.psi <= 1.0


@given(m=st.integers(2, 12), n=st.integers(1, 20),
       seed=st.integers(0, 10_000))
def test_mixing_preserves_mean_any_valid_w(m, n, seed):
    rng = np.random.default_rng(seed)
    topo = ["ring", "exp", "full", "random"][seed % 4]
    spec = gossip.make_gossip(topo, m, degree=3, seed=seed)
    z = jnp.asarray(rng.normal(size=(m, n)), jnp.float32)
    out = mixing.mix_dense(spec.matrix, {"p": z})["p"]
    np.testing.assert_allclose(np.mean(np.asarray(out), 0),
                               np.mean(np.asarray(z), 0), atol=1e-5)


@given(lr=st.floats(1e-4, 0.5), lam_mult=st.floats(0.51, 10.0),
       K=st.integers(1, 30))
def test_gamma_identities(lr, lam_mult, K):
    lam = lr * lam_mult  # ensures lr <= 2*lam (paper's condition)
    g = admm.gamma(lr, lam, K)
    gk = np.asarray(admm.gamma_k(lr, lam, K))
    np.testing.assert_allclose(gk.sum(), g, rtol=1e-4, atol=1e-7)
    if lam_mult >= 1.0:  # lr <= lam: weights are positive and monotone
        assert 0.0 < g <= 1.0 + 1e-9
        assert (gk >= 0).all()
        # gamma_k increases in k (later grads weigh more)
        assert (np.diff(gk) >= -1e-12).all()


@given(n=st.integers(50, 2000), m=st.integers(2, 20),
       alpha=st.floats(0.05, 10.0), seed=st.integers(0, 1000))
def test_dirichlet_partition_is_a_partition(n, m, alpha, seed):
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 10, size=n)
    parts = dirichlet_partition(labels, m, alpha, seed=seed, min_size=0)
    allidx = np.concatenate(parts)
    assert len(allidx) == n
    assert len(np.unique(allidx)) == n  # disjoint cover


@given(n=st.integers(10, 500), m=st.integers(2, 10))
def test_iid_partition_is_balanced(n, m):
    parts = iid_partition(n, m)
    sizes = [len(p) for p in parts]
    assert max(sizes) - min(sizes) <= 1
    assert sum(sizes) == n


@given(seed=st.integers(0, 500), K=st.integers(1, 8),
       lam=st.floats(0.05, 1.0))
def test_lemma2_property(seed, K, lam):
    """Lemma 2 closed form holds for arbitrary gradient sequences."""
    lr = min(0.1, 2 * lam)
    rng = np.random.default_rng(seed)
    d = 6
    anchor = {"w": jnp.asarray(rng.normal(size=d), jnp.float32)}
    dual = {"w": jnp.asarray(rng.normal(size=d), jnp.float32)}
    gs = jnp.asarray(rng.normal(size=(K, d)), jnp.float32)
    params = anchor
    for k in range(K):
        params = admm.local_step(params, {"w": gs[k]}, dual, anchor,
                                 lr=lr, lam=lam)
    closed = admm.lemma2_delta({"w": gs}, dual, lr=lr, lam=lam, K=K)
    np.testing.assert_allclose(np.asarray(params["w"] - anchor["w"]),
                               np.asarray(closed["w"]), rtol=2e-4, atol=2e-5)


@given(shape=st.sampled_from([(37,), (130,), (4, 33)]),
       lr=st.floats(1e-3, 0.3), lam=st.floats(0.05, 2.0),
       seed=st.integers(0, 100))
def test_kernel_matches_ref_property(shape, lr, lam, seed):
    from repro.kernels import ops, ref
    rng = np.random.default_rng(seed)
    x, g, d, a = (jnp.asarray(rng.normal(size=shape), jnp.float32)
                  for _ in range(4))
    np.testing.assert_allclose(
        np.asarray(ops.admm_update(x, g, d, a, lr=lr, lam=lam)),
        np.asarray(ref.admm_update(x, g, d, a, lr=lr, lam=lam)),
        rtol=1e-5, atol=1e-5)


@given(b=st.integers(1, 3), s=st.integers(2, 40), d=st.integers(1, 8),
       n=st.integers(1, 4), chunk=st.integers(1, 16),
       seed=st.integers(0, 1000))
def test_chunked_ssm_invariant_to_chunk_size(b, s, d, n, chunk, seed):
    """chunked_ssm == chunked_linear_scan oracle for every chunking."""
    from repro.models import mamba
    rng = np.random.default_rng(seed)
    a = jnp.asarray(np.exp(-np.abs(rng.normal(size=(b, s, d, n)))),
                    jnp.float32)
    bb = jnp.asarray(rng.normal(size=(b, s, d, n)), jnp.float32)
    h0 = jnp.asarray(rng.normal(size=(b, d, n)), jnp.float32)

    h_all, h_last = mamba.chunked_linear_scan(a, bb, h0, chunk)

    def ab_fn(inp):
        ac, bc = inp
        return ac, bc

    def y_fn(h, inp):
        return h

    y, h_last2 = mamba.chunked_ssm(ab_fn, y_fn, (a, bb), h0, chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(h_all),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h_last2), np.asarray(h_last),
                               rtol=1e-5, atol=1e-5)


def _gq_case(m, n, seed, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    w = rng.random((m, m)).astype(np.float32)
    w /= w.sum(1, keepdims=True)
    z = jnp.asarray(rng.normal(size=(m, n)), dtype)
    r = jnp.asarray(rng.normal(size=(m, n)) * 0.01, jnp.float32)
    u = jnp.asarray(rng.random((m, n)), jnp.float32)
    return jnp.asarray(w), z, r, u


@given(m=st.integers(2, 9), n=st.integers(1, 700),
       bits=st.sampled_from([4, 8]), masked=st.booleans(),
       dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
       seed=st.integers(0, 10_000))
def test_fused_gossip_quant_equals_composed(m, n, bits, masked, dtype, seed):
    """The fused quantize+EF+mix kernel == the composed oracle chain for
    arbitrary client counts, ragged leaf sizes, bit widths, dtypes, and
    participation masks (both sides consume the same uniform draws)."""
    from repro.kernels import ops, ref
    w, z, r, u = _gq_case(m, n, seed, dtype)
    active = None
    if masked:
        rng = np.random.default_rng(seed + 1)
        act = rng.random(m) < 0.5
        act[seed % m] = True            # at least one active client
        active = jnp.asarray(act)
    y, rout = ops.quantize_mix_leaf(w, z, r, u, active, bits=bits)
    qmax = float(2 ** (bits - 1) - 1)
    e = z.astype(jnp.float32) + r
    scale = (jnp.maximum(jnp.max(jnp.abs(e), 1), 1e-12) / qmax).reshape(-1, 1)
    yr, rr = ref.gossip_quant(w, z, r, u, scale, active, bits=bits)
    tol = dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), **tol)
    np.testing.assert_allclose(np.asarray(rout, np.float32),
                               np.asarray(rr, np.float32), **tol)


@given(m=st.integers(2, 6), n=st.integers(1, 64), rounds=st.integers(1, 5),
       bits=st.sampled_from([4, 8]), seed=st.integers(0, 10_000))
def test_fused_error_feedback_telescopes(m, n, rounds, bits, seed):
    """EF telescoping survives the fused path: over T rounds,
    sum_t W @ zhat_t = W @ (sum_t z_t - r_T), i.e. the compression error
    the network has seen so far is exactly the carried residual."""
    from repro.kernels import ops
    rng = np.random.default_rng(seed)
    w = rng.random((m, m)).astype(np.float32)
    w /= w.sum(1, keepdims=True)
    w = jnp.asarray(w)
    r = jnp.zeros((m, n), jnp.float32)
    y_sum = jnp.zeros((m, n), jnp.float32)
    z_sum = jnp.zeros((m, n), jnp.float32)
    for _ in range(rounds):
        z = jnp.asarray(rng.normal(size=(m, n)), jnp.float32)
        u = jnp.asarray(rng.random((m, n)), jnp.float32)
        y, r = ops.quantize_mix_leaf(w, z, r, u, bits=bits)
        y_sum = y_sum + y
        z_sum = z_sum + z
    np.testing.assert_allclose(np.asarray(y_sum + w @ r),
                               np.asarray(w @ z_sum),
                               rtol=2e-4, atol=2e-4 * rounds)


@given(m=st.integers(2, 8), k=st.integers(1, 3), n=st.sampled_from([1, 2, 4]),
       seed=st.integers(0, 100))
def test_microbatch_exactness_property(m, k, n, seed):
    """Grad accumulation over n splits == full batch, any (m, K, n)."""
    import jax
    from repro.core import DFLConfig, make_gossip, make_train_round
    from repro.core.dfl import init_state
    b = 4 * n
    rng = np.random.default_rng(seed)

    def loss_fn(p, batch, r):
        return jnp.mean((batch["x"] @ p["w"] - batch["y"]) ** 2)

    outs = []
    for nn in (1, n):
        cfg = DFLConfig(algorithm="dfedadmm", m=m, K=k, topology="ring",
                        microbatches=nn)
        spec = make_gossip("ring", m)
        params = {"w": jnp.ones((5, 2), jnp.float32)}
        state = init_state(params, cfg)
        batches = {"x": jnp.asarray(rng.normal(size=(m, k, b, 5)),
                                    jnp.float32),
                   "y": jnp.asarray(rng.normal(size=(m, k, b, 2)),
                                    jnp.float32)}
        w = jnp.asarray(spec.matrix, jnp.float32)
        rf = make_train_round(loss_fn, cfg, spec=spec)
        out, _ = jax.jit(rf)(state, batches, w)
        outs.append(out.params["w"])
        rng = np.random.default_rng(seed)   # same batches both times
    np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(outs[1]),
                               rtol=1e-5, atol=1e-6)


@given(m=st.integers(2, 12), seed=st.integers(0, 1000),
       p=st.floats(0.1, 0.9), fresh_p=st.floats(0.1, 1.0))
def test_effective_matrix_row_stochastic_on_active_subgraph(m, seed, p,
                                                           fresh_p):
    """The async tick's effective mixing matrix stays row-stochastic and
    non-negative for any topology x receiving/fresh masks, with identity
    rows for clients that sit the tick out (Definition 1 on the
    effective subgraph)."""
    from repro.core.async_engine import effective_matrix
    rng = np.random.default_rng(seed)
    topo = ["ring", "exp", "full", "random"][seed % 4]
    spec = gossip.make_gossip(topo, m, degree=3, seed=seed)
    receiving = rng.random(m) < p
    fresh = rng.random(m) < fresh_p
    wm = effective_matrix(spec.matrix, receiving, fresh)
    np.testing.assert_allclose(wm.sum(axis=1), 1.0, atol=1e-12)
    assert (wm >= 0.0).all()
    for i in np.flatnonzero(~receiving):
        assert wm[i, i] == 1.0 and np.count_nonzero(wm[i]) == 1
    # symmetric masks == the participation machinery's masked plan
    np.testing.assert_array_equal(
        effective_matrix(spec.matrix, receiving, receiving),
        gossip.mask_and_renormalize(spec.matrix, receiving))


def _support_matrix(m, seed, weighted=True):
    """Random ragged-support weight matrix with guaranteed self-loops."""
    rng = np.random.default_rng(seed)
    w = rng.random((m, m)).astype(np.float32)
    w[rng.random((m, m)) < 0.4] = 0.0
    np.fill_diagonal(w, rng.random(m).astype(np.float32) * 0.9 + 0.1)
    if not weighted:
        w = (w > 0).astype(np.float32)
    return w


@given(m=st.integers(2, 8), seed=st.integers(0, 1000),
       agg_name=st.sampled_from(["mean", "trimmed_mean", "median", "krum"]))
def test_robust_aggregator_permutation_equivariant(m, seed, agg_name):
    """Relabeling the clients relabels the output: A(Pz, PWP^T) = P A(z, W)
    for every registered builtin aggregator (no client is special)."""
    from repro.core import threat
    rng = np.random.default_rng(seed)
    # jitter guarantees unique values so krum's tie-break never fires
    vals = rng.normal(size=(m, 4)) + 1e-3 * rng.random((m, 4))
    z = {"a": jnp.asarray(vals, jnp.float32)}
    w = _support_matrix(m, seed)
    perm = rng.permutation(m)
    p = np.eye(m, dtype=np.float32)[perm]
    agg = {"mean": threat.MeanAggregator(),
           "trimmed_mean": threat.TrimmedMeanAggregator(0.25),
           "median": threat.MedianAggregator(),
           "krum": threat.KrumAggregator(0.25)}[agg_name]
    out = np.asarray(agg.aggregate(z, jnp.asarray(w))["a"])
    zp = {"a": jnp.asarray(vals[perm], jnp.float32)}
    wp = p @ w @ p.T
    outp = np.asarray(agg.aggregate(zp, jnp.asarray(wp))["a"])
    np.testing.assert_allclose(outp, out[perm], rtol=1e-5, atol=1e-5)


@given(m=st.integers(2, 9), d=st.integers(1, 12), seed=st.integers(0, 1000))
def test_trimmed_mean_trim0_reduces_to_weighted_mean(m, d, seed):
    """Zero adversaries assumed -> zero trimming: the trimmed mean with
    trim=0 IS the renormalized weighted gossip mean on any support."""
    from repro.core import threat
    rng = np.random.default_rng(seed)
    z = {"a": jnp.asarray(rng.normal(size=(m, d)), jnp.float32)}
    w = jnp.asarray(_support_matrix(m, seed))
    out = threat.TrimmedMeanAggregator(0.0).aggregate(z, w)
    ref = threat.MeanAggregator().aggregate(z, w)
    np.testing.assert_allclose(np.asarray(out["a"]), np.asarray(ref["a"]),
                               rtol=1e-4, atol=1e-5)


@given(m=st.integers(1, 6), d=st.integers(1, 64),
       clip=st.floats(0.1, 10.0), scale=st.floats(0.01, 100.0),
       seed=st.integers(0, 1000))
def test_dp_codec_clip_bound_and_ef_identity(m, d, clip, scale, seed):
    """For any message/residual and any clip: with noise=0 the decoded
    wire never exceeds the L2 bound, and the clipping error rides the
    residual exactly (wire + residual = error-compensated message)."""
    import jax

    from repro.core.threat import DPCodec
    rng = np.random.default_rng(seed)
    z = {"a": jnp.asarray(scale * rng.normal(size=(m, d)), jnp.float32)}
    r0 = {"a": jnp.asarray(scale * rng.normal(size=(m, d)) * 0.1,
                           jnp.float32)}
    codec = DPCodec(clip=clip, noise=0.0)
    wire, resid = codec.encode(z, resid=r0, rng=jax.random.PRNGKey(seed))
    out = np.asarray(codec.decode(wire)["a"])
    norms = np.linalg.norm(out.reshape(m, -1), axis=1)
    assert (norms <= clip * (1 + 1e-5) + 1e-6).all()
    np.testing.assert_allclose(
        out + np.asarray(resid["a"]),
        np.asarray(z["a"]) + np.asarray(r0["a"]), rtol=1e-4, atol=1e-4)


@given(m=st.integers(2, 10), seed=st.integers(0, 1000),
       tick_s=st.floats(0.004, 0.1), max_staleness=st.integers(0, 5),
       mode=st.sampled_from(["full", "uniform", "fraction"]))
def test_async_scheduler_invariants(m, seed, tick_s, max_staleness, mode):
    """For random networks x topologies x participation specs: per-client
    virtual clocks never decrease, fresh ages never exceed the staleness
    cap, and the reported staleness telemetry respects the cap."""
    from repro.core import (DFLConfig, ParticipationSpec, make_network)
    from repro.core.async_engine import AsyncScheduler
    net = make_network(["lognormal", "wan-lan", "uniform"][seed % 3], m,
                       seed=seed)
    specs = gossip.time_varying_specs("random", m, 8, degree=3,
                                      base_seed=seed)
    part = ParticipationSpec()
    if mode == "uniform":
        part = ParticipationSpec(mode="uniform", p=0.6, seed=seed)
    elif mode == "fraction":
        part = ParticipationSpec(mode="fraction", p=0.5, seed=seed)
    cfg = DFLConfig(algorithm="dfedadmm", m=m, K=2, topology="random",
                    degree=3, network=net, participation=part,
                    execution="async", tick_s=tick_s,
                    max_staleness=max_staleness)
    sched = AsyncScheduler(cfg, net, specs, bytes_per_client=1000)
    prev_clock = sched.clock.copy()
    prev_rounds = sched.rounds_done.copy()
    for t in range(8):
        ev = sched.step(t)
        assert (sched.clock >= prev_clock - 1e-15).all()
        assert (sched.rounds_done >= prev_rounds).all()
        prev_clock = sched.clock.copy()
        prev_rounds = sched.rounds_done.copy()
        assert 0 <= ev.staleness <= max_staleness
        assert (ev.ages[ev.fresh] <= max_staleness).all()
        assert (ev.ages >= 0).all()
        assert ev.sim_dt >= 0.0
        assert (ev.steps[~ev.active] == 0).all()
        assert (ev.steps[ev.active] == cfg.K).all()


# ---------------------------------------------------------------------------
# Variance-reduction solver invariants (scaffold / dfedtrack)
# ---------------------------------------------------------------------------

def _vr_run(algo, m, K, rounds, seed, topo="ring"):
    """Run ``rounds`` full-participation gossip rounds; return final state."""
    import jax
    from repro.core import DFLConfig, make_gossip, make_train_round
    from repro.core.dfl import init_state
    rng = np.random.default_rng(seed)
    params = {"w": jnp.asarray(rng.normal(size=(6,)), jnp.float32)}

    def loss(p, batch, r):
        return jnp.mean((p["w"] - batch["t"]) ** 2)

    cfg = DFLConfig(algorithm=algo, m=m, K=K, lr=0.1, weight_decay=0.0,
                    topology=topo)
    spec = make_gossip(topo, m, weights="metropolis")
    plan = jnp.asarray(spec.matrix, jnp.float32)
    state = init_state(params, cfg, seed=seed)
    rf = jax.jit(make_train_round(loss, cfg, spec=spec))
    for t in range(rounds):
        r2 = np.random.default_rng(seed * 977 + t)
        batches = {"t": jnp.asarray(r2.normal(size=(m, K, 6)), jnp.float32)}
        state, _ = rf(state, batches, plan)
    return state


@settings(max_examples=10)
@given(m=st.integers(2, 6), K=st.integers(1, 4), rounds=st.integers(1, 3),
       topo=st.sampled_from(["ring", "full", "exp"]),
       seed=st.integers(0, 1000))
def test_scaffold_corrections_sum_to_zero_full_participation(m, K, rounds,
                                                             topo, seed):
    """SCAFFOLD's correction ĉ_i − c_i sums to zero over the population
    at full participation: metropolis weights are doubly stochastic, so
    gossip preserves Σc — the variate estimates never inject net drift
    into the population mean, for any topology / K / round count."""
    state = _vr_run("scaffold", m, K, rounds, seed, topo=topo)
    cv = np.asarray(state.solver["cv"]["w"], np.float64)
    ch = np.asarray(state.comm["track"]["w"], np.float64)
    scale = max(1.0, np.abs(cv).max())
    np.testing.assert_allclose((ch - cv).sum(axis=0), 0.0,
                               atol=1e-5 * m * scale)


@settings(max_examples=10)
@given(m=st.integers(2, 6), K=st.integers(1, 4), rounds=st.integers(1, 4),
       topo=st.sampled_from(["ring", "full", "exp"]),
       seed=st.integers(0, 1000))
def test_tracking_variable_conserved_under_row_stochastic_plans(m, K,
                                                                rounds,
                                                                topo, seed):
    """Gradient tracking's defining invariant: Σ_i t_i == Σ_i d_i after
    every round.  The message t + d_new − d_prev telescopes the local
    descent directions, and doubly stochastic mixing preserves the sum —
    so the population-mean tracker always equals the population-mean
    descent direction."""
    state = _vr_run("dfedtrack", m, K, rounds, seed, topo=topo)
    t = np.asarray(state.comm["track"]["w"], np.float64)
    d = np.asarray(state.solver["d_prev"]["w"], np.float64)
    scale = max(1.0, np.abs(d).max())
    np.testing.assert_allclose(t.sum(axis=0), d.sum(axis=0),
                               atol=1e-5 * m * scale)


@settings(max_examples=10)
@given(m=st.integers(2, 6), seed=st.integers(0, 1000),
       topo=st.sampled_from(["ring", "full"]))
def test_scaffold_zero_variates_reduce_to_dpsgd_bitwise(m, seed, topo):
    """With c_i = c = 0 (the init state) and K = 1, SCAFFOLD's corrected
    step IS plain D-PSGD: the first round must match bitwise, params and
    telemetry both.  The two algorithms compile to different XLA graphs
    (scaffold's correction add changes what fuses into an FMA), so the
    fixture keeps every product exact — lr = 0.125 and an 8-vector loss
    (gradient scale 2/8 = 0.25) — making fusion differences invisible."""
    import jax
    from repro.core import DFLConfig, make_gossip, make_train_round
    from repro.core.dfl import init_state
    rng = np.random.default_rng(seed)
    params = {"w": jnp.asarray(rng.normal(size=(8,)), jnp.float32)}
    batches = {"t": jnp.asarray(rng.normal(size=(m, 1, 8)), jnp.float32)}

    def loss(p, batch, r):
        return jnp.mean((p["w"] - batch["t"]) ** 2)

    spec = gossip.make_gossip(topo, m, weights="metropolis")
    plan = jnp.asarray(spec.matrix, jnp.float32)
    outs = {}
    for algo in ("scaffold", "dpsgd"):
        cfg = DFLConfig(algorithm=algo, m=m, K=1, lr=0.125,
                        weight_decay=0.0, topology=topo)
        state = init_state(params, cfg, seed=seed)
        rf = jax.jit(make_train_round(loss, cfg, spec=spec))
        st, met = rf(state, batches, plan)
        outs[algo] = (np.asarray(st.params["w"]), float(met["loss"]))
    np.testing.assert_array_equal(outs["scaffold"][0], outs["dpsgd"][0])
    assert outs["scaffold"][1] == outs["dpsgd"][1]
