"""The paper's own backbones (MLP / CNN / ResNet18-GN) under DFL."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DFLConfig, simulate
from repro.models.vision import (build_vision, group_norm,
                                 vision_loss_fn)

pytestmark = pytest.mark.slow  # jit/subprocess-heavy: excluded from the fast tier


@pytest.mark.parametrize("name,kw,shape", [
    ("mlp", dict(in_dim=64, classes=10), (4, 64)),
    ("cnn", dict(img=16, classes=10), (4, 16, 16, 3)),
    ("resnet18", dict(classes=10), (2, 16, 16, 3)),
])
def test_backbone_forward(name, kw, shape):
    params, apply = build_vision(name, jax.random.PRNGKey(0), **kw)
    x = jnp.asarray(np.random.default_rng(0).normal(size=shape), jnp.float32)
    out = apply(params, x)
    assert out.shape == (shape[0], 10)
    assert np.all(np.isfinite(np.asarray(out)))


def test_group_norm_normalises():
    x = jnp.asarray(np.random.default_rng(1).normal(size=(2, 4, 4, 16)) * 5
                    + 3, jnp.float32)
    y = group_norm(x, jnp.ones(16), jnp.zeros(16), groups=4)
    yn = np.asarray(y).reshape(2, -1, 4, 4)
    assert abs(float(np.mean(yn))) < 0.1
    assert abs(float(np.std(np.asarray(y))) - 1.0) < 0.15


def test_cnn_dfl_round_learns():
    params, apply = build_vision("cnn", jax.random.PRNGKey(0), img=8,
                                 classes=4)
    loss = vision_loss_fn(apply)
    m, K = 4, 2
    rng0 = np.random.default_rng(0)
    centers = rng0.normal(size=(4, 8, 8, 3)).astype(np.float32)

    def sampler(t):
        r = np.random.default_rng(t)
        y = r.integers(0, 4, (m, K, 8))
        x = centers[y] * 0.5 + 0.3 * r.normal(size=(m, K, 8, 8, 8, 3))
        return {"x": jnp.asarray(x, jnp.float32), "y": jnp.asarray(y)}

    cfg = DFLConfig(algorithm="dfedadmm", m=m, K=K, topology="ring",
                    lr=0.01, lam=0.5)
    st, hist = simulate(loss, None, params, cfg, sampler, rounds=10)
    assert np.isfinite(hist["loss"]).all()
    assert hist["loss"][-1] < hist["loss"][0]


def test_resnet_dfl_round_runs():
    params, apply = build_vision("resnet18", jax.random.PRNGKey(0), classes=4)
    loss = vision_loss_fn(apply)
    m, K = 2, 1

    def sampler(t):
        r = np.random.default_rng(t)
        return {"x": jnp.asarray(r.normal(size=(m, K, 2, 16, 16, 3)),
                                 jnp.float32),
                "y": jnp.asarray(r.integers(0, 4, (m, K, 2)))}

    cfg = DFLConfig(algorithm="dfedadmm", m=m, K=K, topology="ring",
                    lr=0.01, lam=0.5)
    st, hist = simulate(loss, None, params, cfg, sampler, rounds=2)
    assert np.isfinite(hist["loss"]).all()
