"""MoE layer: router invariants, capacity behaviour, oracle agreement."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import moe


def _cfg(**kw):
    base = dict(name="moe-test", arch_type="moe", num_layers=1, d_model=32,
                num_heads=4, num_kv_heads=2, d_ff=48, vocab_size=64,
                num_experts=4, experts_per_token=2, capacity_factor=8.0,
                dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


def test_router_topk_weights_normalised():
    logits = jnp.asarray(np.random.default_rng(0).normal(size=(10, 6)),
                         jnp.float32)
    w, idx, probs = moe.router_topk(logits, 3)
    np.testing.assert_allclose(np.sum(np.asarray(w), -1), 1.0, rtol=1e-5)
    assert np.all(np.asarray(w) >= 0)
    # indices are the true top-k of the softmax probs
    ref = np.argsort(-np.asarray(probs), axis=-1)[:, :3]
    assert set(map(tuple, np.sort(np.asarray(idx), -1))) == \
        set(map(tuple, np.sort(ref, -1)))


def test_moe_matches_dense_oracle_with_high_capacity():
    cfg = _cfg()
    rng = jax.random.PRNGKey(0)
    params = moe.init_moe_params(rng, cfg, jnp.float32)
    x = jnp.asarray(np.random.default_rng(1).normal(size=(2, 8, 32)) * 0.3,
                    jnp.float32)
    out, aux = moe.moe_block(params, x, cfg)
    ref = moe.moe_block_dense_ref(params, x, cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4,
                               atol=2e-4)
    assert float(aux) > 0.0


def test_capacity_drops_tokens():
    """With tiny capacity some tokens overflow -> output differs from the
    no-drop oracle but remains finite."""
    cfg = _cfg(capacity_factor=0.25)
    params = moe.init_moe_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jnp.asarray(np.random.default_rng(2).normal(size=(2, 16, 32)),
                    jnp.float32)
    out, _ = moe.moe_block(params, x, cfg)
    ref = moe.moe_block_dense_ref(params, x, cfg)
    assert np.all(np.isfinite(np.asarray(out)))
    assert not np.allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


def test_load_balance_loss_uniform_router():
    """A perfectly uniform router gives the minimal aux value (= 1)."""
    e, t = 8, 256
    probs = jnp.full((t, e), 1.0 / e)
    idx = jnp.stack([jnp.arange(t) % e, (jnp.arange(t) + 1) % e], -1)
    lb = moe.load_balance_loss(probs, idx, e)
    np.testing.assert_allclose(float(lb), 1.0, rtol=1e-5)


def test_aux_loss_increases_with_imbalance():
    e, t = 4, 128
    uniform = jnp.full((t, e), 1.0 / e)
    skew = jnp.concatenate([jnp.full((t, 1), 0.97),
                            jnp.full((t, e - 1), 0.01)], -1)
    idx_u = (jnp.arange(t) % e)[:, None]
    idx_s = jnp.zeros((t, 1), jnp.int32)
    assert float(moe.load_balance_loss(skew, idx_s, e)) > \
        float(moe.load_balance_loss(uniform, idx_u, e))


def test_grouped_dispatch_matches_single_group():
    """With capacity high enough for zero drops, GShard grouping is exact:
    g-token groups give the same output as one global group."""
    cfg_1 = _cfg(capacity_factor=8.0, moe_group_size=0)
    cfg_g = _cfg(capacity_factor=8.0, moe_group_size=8)
    params = moe.init_moe_params(jax.random.PRNGKey(3), cfg_1, jnp.float32)
    x = jnp.asarray(np.random.default_rng(4).normal(size=(2, 16, 32)) * 0.3,
                    jnp.float32)
    out1, aux1 = moe.moe_block(params, x, cfg_1)
    outg, auxg = moe.moe_block(params, x, cfg_g)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(outg),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(float(aux1), float(auxg), rtol=1e-6)
