"""End-to-end CLI driver smoke tests (subprocess, smoke configs).

These exercise the public entry points a user actually types — the same
code paths the examples and the README quickstart use."""
import os
import subprocess
import sys

import jax
import pytest

pytestmark = pytest.mark.slow  # jit/subprocess-heavy: excluded from the fast tier


SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(mod, *args, timeout=600):
    env = dict(os.environ, PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-m", mod, *args], env=env,
                       capture_output=True, text=True, timeout=timeout)
    assert r.returncode == 0, (r.stdout[-1500:], r.stderr[-3000:])
    return r.stdout


def test_train_cli_smoke():
    out = _run("repro.launch.train", "--arch", "llama3-8b", "--smoke",
               "--algorithm", "dfedadmm", "--rounds", "2", "--m", "2",
               "--k", "1", "--batch", "2", "--seq", "16")
    assert "final loss=" in out


def test_train_cli_microbatch_sam():
    out = _run("repro.launch.train", "--arch", "zamba2-1.2b", "--smoke",
               "--algorithm", "dfedadmm_sam", "--rounds", "2", "--m", "2",
               "--k", "1", "--batch", "4", "--seq", "16",
               "--microbatches", "2")
    assert "final loss=" in out


def test_train_cli_adaptive_solver_and_randk():
    """--algorithm resolves via the solver registry (the adaptive-lambda
    demo ships as a registered solver, not a dfl.py branch) and the
    rand-k codec is selectable on the wire."""
    out = _run("repro.launch.train", "--arch", "llama3-8b", "--smoke",
               "--algorithm", "dfedadmm_adaptive", "--rounds", "2",
               "--m", "2", "--k", "1", "--batch", "2", "--seq", "16",
               "--codec", "randk", "--codec-k", "32")
    assert "final loss=" in out
    assert "randk" in out


def test_serve_cli_smoke():
    out = _run("repro.launch.serve", "--arch", "falcon-mamba-7b", "--smoke",
               "--batch", "2", "--prompt-len", "16", "--gen", "4")
    assert "tok/s" in out


@pytest.mark.skipif(not hasattr(jax.sharding, "AxisType"),
                    reason="jax.sharding.AxisType unavailable in this jax")
def test_dryrun_cli_no_save(tmp_path):
    out = _run("repro.launch.dryrun", "--arch", "llama3-8b",
               "--shape", "decode_32k", "--kv-shard", "seq", "--no-save")
    assert "[dryrun] OK" in out
