"""Pin the DFedADMM implementation to the paper's closed-form math."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import admm, sam


def quad_loss(target):
    def loss(params, batch, rng):
        return 0.5 * jnp.sum((params["w"] - target - batch) ** 2)
    return loss


def _run_inner_loop(K=7, lr=0.03, lam=0.2, seed=0, rho=0.0):
    """Run Alg. 1 lines 3-13 recording every inner gradient."""
    rng = np.random.default_rng(seed)
    d = 12
    target = jnp.asarray(rng.normal(size=d), jnp.float32)
    anchor = {"w": jnp.asarray(rng.normal(size=d), jnp.float32)}
    dual = {"w": jnp.asarray(rng.normal(size=d) * 0.1, jnp.float32)}
    batches = jnp.asarray(rng.normal(size=(K, d)) * 0.3, jnp.float32)

    loss = quad_loss(target)
    grad_fn = sam.sam_grad_fn(loss, rho)
    params = anchor
    grads_seq = []
    for k in range(K):
        g = grad_fn(params, batches[k], None)
        grads_seq.append(g)
        params = admm.local_step(params, g, dual, anchor, lr=lr, lam=lam)
    grads_seq = {"w": jnp.stack([g["w"] for g in grads_seq])}
    return params, anchor, dual, grads_seq


@pytest.mark.parametrize("K", [1, 3, 7])
@pytest.mark.parametrize("lam", [0.1, 0.5])
def test_lemma2_closed_form(K, lam):
    lr = 0.03
    params_K, anchor, dual, grads = _run_inner_loop(K=K, lr=lr, lam=lam)
    delta = admm.lemma2_delta(grads, dual, lr=lr, lam=lam, K=K)
    np.testing.assert_allclose(params_K["w"] - anchor["w"], delta["w"],
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("K", [1, 4])
def test_lemma3_dual_closed_form(K):
    lr, lam = 0.05, 0.25
    params_K, anchor, dual, grads = _run_inner_loop(K=K, lr=lr, lam=lam)
    new_dual = admm.dual_update(dual, params_K, anchor, lam=lam)
    closed = admm.lemma3_dual(grads, dual, lr=lr, lam=lam, K=K)
    np.testing.assert_allclose(new_dual["w"], closed["w"], rtol=1e-5,
                               atol=1e-6)


def test_gamma_sum_identity():
    for lr, lam, K in [(0.1, 0.2, 5), (0.01, 0.1, 20), (0.05, 0.05, 3)]:
        gk = admm.gamma_k(lr, lam, K)
        assert np.isclose(float(jnp.sum(gk)), admm.gamma(lr, lam, K),
                          rtol=1e-6)


def test_message_uses_old_dual():
    """Alg. 1 line 17: z = x_K - lam * ghat^{t-1} (NOT the new dual)."""
    params_K, anchor, dual, _ = _run_inner_loop()
    lam = 0.2
    z = admm.message(params_K, dual, lam=lam)
    np.testing.assert_allclose(z["w"], params_K["w"] - lam * dual["w"],
                               rtol=1e-6)


def test_large_lambda_reduces_to_sgd_with_dual():
    """lam -> inf: proximal term vanishes; update = SGD on (g - dual)."""
    lr, lam = 0.05, 1e8
    params_K, anchor, dual, grads = _run_inner_loop(K=1, lr=lr, lam=lam)
    manual = anchor["w"] - lr * (grads["w"][0] - dual["w"])
    np.testing.assert_allclose(params_K["w"], manual, rtol=1e-5)


def test_sam_reduces_to_plain_at_rho0():
    loss = quad_loss(jnp.zeros(4))
    params = {"w": jnp.asarray([1.0, -2.0, 3.0, 0.5])}
    g0 = sam.sam_grad_fn(loss, 0.0)(params, jnp.zeros(4), None)
    g1 = jax.grad(loss)(params, jnp.zeros(4), None)
    np.testing.assert_allclose(g0["w"], g1["w"])


def test_sam_perturbation_norm():
    rho = 0.3
    g = {"a": jnp.asarray([3.0, 0.0]), "b": jnp.asarray([[4.0]])}
    x = {"a": jnp.zeros(2), "b": jnp.zeros((1, 1))}
    xp = sam.perturb(x, g, rho)
    # ||g|| = 5 -> perturbation = rho * g / 5
    np.testing.assert_allclose(xp["a"], jnp.asarray([0.18, 0.0]), rtol=1e-5)
    np.testing.assert_allclose(xp["b"], jnp.asarray([[0.24]]), rtol=1e-5)


def test_dual_fixed_point_at_consensus():
    """If x_K == anchor the dual is unchanged (no drift, no correction)."""
    anchor = {"w": jnp.ones(5)}
    dual = {"w": jnp.full(5, 0.3)}
    nd = admm.dual_update(dual, anchor, anchor, lam=0.2)
    np.testing.assert_allclose(nd["w"], dual["w"])
