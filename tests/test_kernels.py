"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret
mode (the container is CPU-only; TPU is the compile target)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

SHAPES = [(16,), (1000,), (128, 128), (3, 5, 17), (2, 513, 31)]
DTYPES = [jnp.float32, jnp.bfloat16]


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_admm_update_kernel(shape, dtype):
    rng = np.random.default_rng(hash(shape) % 2**31)
    x, g, d, a = (jnp.asarray(rng.normal(size=shape), dtype)
                  for _ in range(4))
    y = ops.admm_update(x, g, d, a, lr=0.07, lam=0.3)
    yr = ref.admm_update(x, g, d, a, lr=0.07, lam=0.3)
    assert y.dtype == dtype and y.shape == x.shape
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), **_tol(dtype))


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_sumsq_kernel(shape, dtype):
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=shape), dtype)
    s = ops.global_sumsq({"x": x})
    np.testing.assert_allclose(float(s), float(ref.sumsq(x)), rtol=1e-2
                               if dtype == jnp.bfloat16 else 1e-5)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_sam_scale_kernel(shape, dtype):
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=shape), dtype)
    g = jnp.asarray(rng.normal(size=shape), dtype)
    y = ops.sam_scale(x, g, 0.11)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(ref.scale_add(x, g, 0.11),
                                          np.float32), **_tol(dtype))


@pytest.mark.parametrize("m", [4, 8, 16])
@pytest.mark.parametrize("dtype", DTYPES)
def test_gossip_matmul_kernel(m, dtype):
    rng = np.random.default_rng(3)
    w = rng.random((m, m)).astype(np.float32)
    w /= w.sum(1, keepdims=True)
    z = jnp.asarray(rng.normal(size=(m, 3, 50)), dtype)
    y = ops.gossip_mix_leaf(jnp.asarray(w), z)
    zr = ref.gossip_matmul(jnp.asarray(w), z.reshape(m, -1)).reshape(z.shape)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(zr, np.float32), **_tol(dtype))


def test_kernel_traced_scalars_under_jit():
    rng = np.random.default_rng(4)
    x, g, d, a = (jnp.asarray(rng.normal(size=(200,)), jnp.float32)
                  for _ in range(4))

    @jax.jit
    def f(lr):
        return ops.admm_update(x, g, d, a, lr=lr, lam=0.3)

    np.testing.assert_allclose(f(jnp.float32(0.07)),
                               ref.admm_update(x, g, d, a, lr=0.07, lam=0.3),
                               rtol=1e-5, atol=1e-6)


def test_kernel_grad_flows():
    """The fused update stays differentiable (needed inside scan+grad)."""
    x = jnp.ones(100)

    def f(x_):
        y = ops.admm_update(x_, x_ * 2, x_ * 0, x_, lr=0.1, lam=0.5)
        return jnp.sum(y ** 2)

    g = jax.grad(f)(x)
    assert np.all(np.isfinite(np.asarray(g)))


GQ_SHAPES = [  # (m, ...) stacked client leaves, deliberately ragged
    (4, 16), (8, 128), (3, 5, 17), (2, 513, 31), (6, 1000), (5, 4097),
]


def _gq_inputs(shape, dtype, seed):
    m = shape[0]
    rng = np.random.default_rng(seed)
    w = rng.random((m, m)).astype(np.float32)
    w /= w.sum(1, keepdims=True)
    z = jnp.asarray(rng.normal(size=shape), dtype)
    r = jnp.asarray(rng.normal(size=shape) * 0.01, jnp.float32)
    u = jnp.asarray(rng.random(shape), jnp.float32)
    return jnp.asarray(w), z, r, u


def _gq_oracle(w, z, r, u, active=None, *, bits):
    """Composed quantize -> dequantize -> gate -> mix reference on the
    flattened (m, N) planes, scale derived exactly as the fused op does."""
    m = z.shape[0]
    qmax = float(2 ** (bits - 1) - 1)
    e = z.astype(jnp.float32).reshape(m, -1) + r.reshape(m, -1)
    scale = (jnp.maximum(jnp.max(jnp.abs(e), 1), 1e-12) / qmax).reshape(-1, 1)
    y, rr = ref.gossip_quant(w, z.reshape(m, -1), r.reshape(m, -1),
                             u.reshape(m, -1), scale, active, bits=bits)
    return y.reshape(z.shape), rr.reshape(z.shape)


@pytest.mark.parametrize("shape", GQ_SHAPES)
@pytest.mark.parametrize("bits", [4, 8])
@pytest.mark.parametrize("dtype", DTYPES)
def test_gossip_quant_kernel_matches_composed(shape, bits, dtype):
    w, z, r, u = _gq_inputs(shape, dtype, hash(shape) % 2**31 + bits)
    y, rout = ops.quantize_mix_leaf(w, z, r, u, bits=bits)
    yr, rr = _gq_oracle(w, z, r, u, bits=bits)
    assert y.dtype == z.dtype and y.shape == z.shape
    assert rout.dtype == r.dtype and rout.shape == r.shape
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), **_tol(dtype))
    np.testing.assert_allclose(np.asarray(rout, np.float32),
                               np.asarray(rr, np.float32), **_tol(dtype))


@pytest.mark.parametrize("shape", [(4, 16), (6, 1000), (3, 5, 17)])
@pytest.mark.parametrize("bits", [4, 8])
def test_gossip_quant_kernel_masked_matches_composed(shape, bits):
    """Inactive clients mix their raw message and keep their residual —
    gated inside the fused kernel, not by a post-hoc where()."""
    w, z, r, u = _gq_inputs(shape, jnp.float32, 17 + bits)
    m = shape[0]
    active = jnp.asarray(np.arange(m) % 2 == 0)
    y, rout = ops.quantize_mix_leaf(w, z, r, u, active, bits=bits)
    yr, rr = _gq_oracle(w, z, r, u, active, bits=bits)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(rout), np.asarray(rr),
                               rtol=1e-5, atol=1e-6)
    # inactive rows carry their residual through untouched
    for i in np.flatnonzero(~np.asarray(active)):
        np.testing.assert_array_equal(np.asarray(rout[i]), np.asarray(r[i]))


def test_gossip_quant_kernel_under_jit_and_vmapped_w():
    """Trace-compatible: jitted, with a traced gossip matrix (the round
    fn feeds the masked plan as an argument, not a constant)."""
    w, z, r, u = _gq_inputs((4, 200), jnp.float32, 99)

    @jax.jit
    def f(w_):
        return ops.quantize_mix_leaf(w_, z, r, u, bits=8)

    y, rout = f(w)
    yr, rr = _gq_oracle(w, z, r, u, bits=8)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(rout), np.asarray(rr),
                               rtol=1e-5, atol=1e-6)


SSCAN_SHAPES = [  # (B, S, D, N)
    (1, 8, 16, 4),
    (2, 64, 128, 16),
    (1, 513, 96, 16),    # S not a multiple of the chunk, D of the tile
    (3, 130, 257, 8),    # everything ragged
]


@pytest.mark.parametrize("shape", SSCAN_SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_selective_scan_kernel(shape, dtype):
    b, s, d, n = shape
    rng = np.random.default_rng(hash(shape) % 2**31)
    x = jnp.asarray(rng.normal(size=(b, s, d)) * 0.5, dtype)
    dt = jnp.asarray(np.abs(rng.normal(size=(b, s, d))) * 0.1, dtype)
    a_log = jnp.asarray(rng.normal(size=(d, n)) * 0.2, jnp.float32)
    bm = jnp.asarray(rng.normal(size=(b, s, n)) * 0.5, dtype)
    cm = jnp.asarray(rng.normal(size=(b, s, n)) * 0.5, dtype)
    dskip = jnp.asarray(rng.normal(size=(d,)), jnp.float32)
    y, h = ops.selective_scan(x, dt, a_log, bm, cm, dskip)
    yr, hr = ref.selective_scan(x, dt, a_log, bm, cm, dskip,
                                jnp.zeros((b, d, n), jnp.float32))
    assert y.dtype == dtype and y.shape == (b, s, d)
    assert h.shape == (b, d, n) and h.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), **_tol(dtype))
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 2e-5,
                               atol=2e-2 if dtype == jnp.bfloat16 else 1e-5)


def test_selective_scan_carries_state():
    """Two half-sequences with carried h == one full sequence."""
    b, s, d, n = 2, 32, 64, 8
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.normal(size=(b, s, d)) * 0.5, jnp.float32)
    dt = jnp.asarray(np.abs(rng.normal(size=(b, s, d))) * 0.1, jnp.float32)
    a_log = jnp.asarray(rng.normal(size=(d, n)) * 0.2, jnp.float32)
    bm = jnp.asarray(rng.normal(size=(b, s, n)) * 0.5, jnp.float32)
    cm = jnp.asarray(rng.normal(size=(b, s, n)) * 0.5, jnp.float32)
    dskip = jnp.asarray(rng.normal(size=(d,)), jnp.float32)
    y_full, h_full = ops.selective_scan(x, dt, a_log, bm, cm, dskip)
    h = None
    ys = []
    for lo, hi in ((0, s // 2), (s // 2, s)):
        y, h = ops.selective_scan(x[:, lo:hi], dt[:, lo:hi], a_log,
                                  bm[:, lo:hi], cm[:, lo:hi], dskip, h0=h)
        ys.append(y)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(ys, 1)),
                               np.asarray(y_full), rtol=2e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_full),
                               rtol=2e-5, atol=1e-5)
