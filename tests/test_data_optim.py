"""Data pipeline + optimizer unit tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.federated import (dirichlet_partition,
                                  partition_stats)
from repro.data.synthetic import (SyntheticClassification, SyntheticLM,
                                  make_dfl_lm_sampler, make_model_batch)
from repro.optim import adamw, init_opt_state, sgd, sgd_momentum
from repro.optim.schedules import constant, exp_decay, warmup_cosine


def test_dirichlet_more_heterogeneous_at_small_alpha():
    labels = np.random.default_rng(0).integers(0, 10, size=5000)
    h_small = partition_stats(labels, dirichlet_partition(labels, 20, 0.1,
                                                          seed=1))
    h_big = partition_stats(labels, dirichlet_partition(labels, 20, 10.0,
                                                        seed=1))
    assert h_small["heterogeneity"] > h_big["heterogeneity"]


def test_client_sampler_shapes():
    task = SyntheticClassification(n_train=500, n_test=100)
    parts = task.partition(5, 0.3)
    sampler = task.client_sampler(parts, batch=8, K=3)
    b = sampler(0)
    assert b["x"].shape == (5, 3, 8, task.dim)
    assert b["y"].shape == (5, 3, 8)


def test_synthetic_lm_temperature_changes_distribution():
    lm = SyntheticLM(vocab=64)
    a = lm.sample_tokens(4, 200, temp=0.3, seed=1)
    b = lm.sample_tokens(4, 200, temp=3.0, seed=1)
    # hotter chains have higher empirical entropy
    def ent(x):
        c = np.bincount(x.ravel(), minlength=64) + 1e-9
        p = c / c.sum()
        return -(p * np.log(p)).sum()
    assert ent(b) > ent(a)


def test_dfl_lm_sampler_layout():
    from repro.configs import get_smoke_config
    cfg = get_smoke_config("llama3-8b")
    sampler = make_dfl_lm_sampler(cfg, m=3, K=2, batch=4, seq=16)
    b = sampler(0)
    assert b["tokens"].shape == (3, 2, 4, 16)
    assert (b["labels"][..., :-1] == b["tokens"][..., 1:]).all()
    assert b["tokens"].max() < cfg.vocab_size


def _quad(params):
    return 0.5 * jnp.sum(params["w"] ** 2)


@pytest.mark.parametrize("opt,lr,steps", [(sgd, 0.1, 60),
                                           (sgd_momentum, 0.02, 150),
                                           (adamw, 0.1, 150)])
def test_optimizers_descend(opt, lr, steps):
    params = {"w": jnp.full(10, 5.0)}
    state = init_opt_state(params)
    for _ in range(steps):
        g = jax.grad(_quad)(params)
        params, state = opt(params, g, state, lr=lr)
    assert float(_quad(params)) < 0.5


def test_schedules():
    assert float(constant(0.1)(100)) == pytest.approx(0.1)
    assert float(exp_decay(0.1, 0.998)(500)) == pytest.approx(
        0.1 * 0.998 ** 500, rel=2e-3)
    wc = warmup_cosine(1.0, 10, 100)
    assert float(wc(0)) == 0.0
    assert float(wc(10)) == pytest.approx(1.0, abs=1e-2)
    assert float(wc(100)) == pytest.approx(0.0, abs=1e-3)


def test_make_model_batch_vlm_audio():
    from repro.configs import get_smoke_config
    v = get_smoke_config("paligemma-3b")
    b = make_model_batch(v, 2, 16)
    assert b["tokens"].shape == (2, 16 - v.prefix_tokens)
    assert b["embeds"].shape == (2, v.prefix_tokens, v.d_model)
    a = get_smoke_config("musicgen-large")
    b = make_model_batch(a, 2, 16)
    assert b["embeds"].shape == (2, 16, a.d_model)
