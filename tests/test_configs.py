"""Assigned-architecture configs: exact spec values + analytic sizes."""
import pytest

from repro.configs import (ARCH_IDS, get_bundle, get_model_config,
                           get_smoke_config, input_specs, shape_applicable)
from repro.configs.shapes import SHAPES

EXPECT = {
    # arch: (layers, d_model, heads, kv, d_ff, vocab, ~params B)
    "minitron-8b": (32, 4096, 32, 8, 16384, 256000, None),
    "musicgen-large": (48, 2048, 32, 32, 8192, 2048, None),
    "llama3-8b": (32, 4096, 32, 8, 14336, 128256, 8.0),
    "falcon-mamba-7b": (64, 4096, 0, 0, 0, 65024, 7.3),
    "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000, 46.7),
    "llama3-405b": (126, 16384, 128, 8, 53248, 128256, 405.0),
    "gemma3-12b": (48, 3840, 16, 8, 15360, 262144, None),
    "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000, 1.2),
    "paligemma-3b": (18, 2048, 8, 1, 16384, 257216, 3.0),
    "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936, 235.0),
}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_exact_assigned_values(arch):
    cfg = get_model_config(arch)
    L, d, h, kv, ff, v, nb = EXPECT[arch]
    assert cfg.num_layers == L and cfg.d_model == d
    assert cfg.num_heads == h and cfg.num_kv_heads == kv
    assert cfg.d_ff == ff and cfg.vocab_size == v


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_count_near_nominal(arch):
    cfg = get_model_config(arch)
    nb = EXPECT[arch][6]
    if nb is None:
        return
    n = cfg.param_count() / 1e9
    assert abs(n - nb) / nb < 0.25, (arch, n)


def test_moe_active_params():
    q = get_model_config("qwen3-moe-235b-a22b")
    assert abs(q.active_param_count() / 1e9 - 22.0) < 3.0
    mx = get_model_config("mixtral-8x7b")
    assert abs(mx.active_param_count() / 1e9 - 12.9) < 2.0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_config_is_reduced(arch):
    sm = get_smoke_config(arch)
    assert sm.num_layers <= 8
    assert sm.d_model <= 512
    assert sm.num_experts <= 4


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("shape", list(SHAPES))
def test_input_specs_shapes(arch, shape):
    bundle = get_bundle(arch)
    cfg = bundle.model
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        assert shape == "long_500k" and not cfg.sub_quadratic
        return
    specs = input_specs(cfg, bundle.parallel, shape)
    s = SHAPES[shape]
    if s.kind == "train":
        lead = (bundle.parallel.dfl_m, bundle.parallel.dfl_k)
        key = "embeds" if cfg.arch_type == "audio" else "tokens"
        assert specs[key].shape[:2] == lead
        assert specs[key].shape[2] == s.global_batch // bundle.parallel.dfl_m
    elif s.kind == "prefill":
        key = "embeds" if cfg.arch_type == "audio" else "tokens"
        assert specs[key].shape[0] == s.global_batch
    else:
        assert "cache" in specs and "token" in specs
        if cfg.uses_attention and cfg.arch_type != "ssm":
            assert specs["cache"]["k"].shape[2] == s.seq_len or \
                specs["cache"]["k"].shape[1] == s.global_batch


def test_long500k_skips_documented():
    skips = [a for a in ARCH_IDS
             if not shape_applicable(get_model_config(a), "long_500k")[0]]
    assert set(skips) == {"minitron-8b", "llama3-8b", "llama3-405b",
                          "musicgen-large", "paligemma-3b",
                          "qwen3-moe-235b-a22b"}
