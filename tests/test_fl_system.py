"""End-to-end DFL behaviour on a controlled synthetic federated task:
the paper's qualitative claims as executable tests."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
from repro.core import DFLConfig, mean_params, simulate
from repro.data.synthetic import SyntheticClassification


def _mlp_init(dim, n_classes, hidden=32, seed=0):
    rng = np.random.default_rng(seed)
    return {
        "w1": jnp.asarray(rng.normal(size=(dim, hidden)) / np.sqrt(dim),
                          jnp.float32),
        "b1": jnp.zeros(hidden),
        "w2": jnp.asarray(rng.normal(size=(hidden, n_classes)) /
                          np.sqrt(hidden), jnp.float32),
        "b2": jnp.zeros(n_classes),
    }


def _mlp_logits(params, x):
    h = jnp.tanh(x @ params["w1"] + params["b1"])
    return h @ params["w2"] + params["b2"]


def _loss(params, batch, rng):
    logits = _mlp_logits(params, batch["x"])
    labels = batch["y"]
    logz = jax.nn.logsumexp(logits, -1)
    gold = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
    return jnp.mean(logz - gold)


@functools.lru_cache(maxsize=1)
def _task():
    return SyntheticClassification(n_classes=8, dim=16, n_train=4000,
                                   n_test=800, noise=1.0, seed=0)


def _acc(params, task):
    logits = _mlp_logits(params, jnp.asarray(task.x_test))
    return float(np.mean(np.argmax(np.asarray(logits), -1) == task.y_test))


def _run(algo, rounds=25, alpha=0.3, topology="ring", m=8, K=5, seed=0,
         **cfg_kw):
    task = _task()
    parts = task.partition(m, alpha, seed=seed)
    sampler0 = task.client_sampler(parts, batch=32, K=K, seed=seed)

    def sampler(t):
        b = sampler0(t)
        return {"x": jnp.asarray(b["x"]), "y": jnp.asarray(b["y"])}

    cfg = DFLConfig(algorithm=algo, m=m, K=K, topology=topology, lr=0.1,
                    lam=0.2, **cfg_kw)
    params = _mlp_init(task.dim, task.n_classes)
    state, hist = simulate(_loss, None, params, cfg, sampler, rounds=rounds,
                           seed=seed)
    return _acc(mean_params(state.params), task), hist


def test_dfedadmm_learns():
    acc, hist = _run("dfedadmm")
    assert acc > 0.65, acc                     # ~8-class task, chance = .125
    assert hist["loss"][-1] < hist["loss"][0] * 0.7


def test_dfedadmm_beats_dpsgd():
    """Paper Table 1: ADMM-based DFL > one-step D-PSGD at equal rounds."""
    acc_admm, _ = _run("dfedadmm", rounds=20)
    acc_dpsgd, _ = _run("dpsgd", rounds=20)
    assert acc_admm > acc_dpsgd


def test_consensus_tighter_than_dfedavg():
    """Dual constraints control inconsistency: consensus distance under
    DFedADMM ends below DFedAvg on heterogeneous data (paper Sec. 1)."""
    _, h_admm = _run("dfedadmm", rounds=25, alpha=0.1)
    _, h_avg = _run("dfedavg", rounds=25, alpha=0.1)
    assert h_admm["consensus_sq"][-1] < h_avg["consensus_sq"][-1]


def test_dual_variables_activate():
    _, hist = _run("dfedadmm", rounds=10)
    assert hist["dual_norm"][0] > 0.0
    assert np.isfinite(hist["dual_norm"]).all()


def test_sam_variant_runs_and_learns():
    acc, _ = _run("dfedadmm_sam", rounds=20, rho=0.05)
    assert acc > 0.6


def test_topology_ordering_trend():
    """Paper Table 2: denser topology -> higher accuracy (Full >= Ring)."""
    accs = {}
    for topo in ("ring", "full"):
        acc = np.mean([_run("dfedadmm", rounds=15, topology=topo,
                            seed=s)[0] for s in (0, 1)])
        accs[topo] = acc
    assert accs["full"] >= accs["ring"] - 0.02  # allow small noise


def test_all_decentralized_baselines_run():
    for algo in ("dfedavg", "dfedavgm", "dfedsam", "dpsgd"):
        acc, hist = _run(algo, rounds=8)
        assert np.isfinite(hist["loss"]).all(), algo
