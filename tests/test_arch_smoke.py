"""Per-assigned-architecture smoke tests: a REDUCED same-family variant
runs one forward + one DFL train step on CPU; output shapes + no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.core import DFLConfig, init_state, make_gossip, make_train_round
from repro.data.synthetic import make_model_batch
from repro.models import build_model

pytestmark = pytest.mark.slow  # jit/subprocess-heavy: excluded from the fast tier



@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward(arch):
    cfg = get_smoke_config(arch)
    assert cfg.num_layers <= 8 and cfg.d_model <= 512
    assert cfg.num_experts <= 4
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 24
    batch = jax.tree.map(jnp.asarray, make_model_batch(cfg, B, S, seed=1))
    from repro.models.model import logits_fn
    logits = logits_fn(params, cfg, batch)
    exp_s = S if cfg.arch_type != "vlm" else S
    assert logits.shape == (B, exp_s, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_dfl_train_step(arch):
    """One full DFedADMM round (the paper's technique) on the reduced arch."""
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    m, K, B, S = 4, 2, 2, 16
    dfl = DFLConfig(algorithm="dfedadmm", m=m, K=K, topology="ring", lr=0.05)
    spec = make_gossip("ring", m)
    round_fn = jax.jit(make_train_round(model.loss, dfl, spec=spec))
    state = init_state(params, dfl)
    batch = jax.tree.map(
        jnp.asarray, make_model_batch(cfg, B, S, seed=2, lead=(m, K)))
    w = jnp.asarray(spec.matrix, jnp.float32)
    new_state, metrics = round_fn(state, batch, w)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["consensus_sq"]))
    assert float(metrics["dual_norm"]) > 0.0  # dual moved away from zero
    for leaf, old in zip(jax.tree.leaves(new_state.params),
                         jax.tree.leaves(state.params)):
        assert leaf.shape == old.shape
        assert np.all(np.isfinite(np.asarray(leaf, np.float32)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_step(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 16
    batch = jax.tree.map(jnp.asarray, make_model_batch(cfg, B, S, seed=3))
    batch.pop("labels", None)
    logits, cache = model.prefill(params, batch, S + 4)
    assert logits.shape == (B, cfg.vocab_size)
    step_in = (jnp.zeros((B, 1, cfg.d_model), jnp.float32)
               if cfg.arch_type == "audio" else jnp.array([1] * B))
    logits2, cache = model.decode_step(params, cache, step_in)
    assert logits2.shape == (B, cfg.vocab_size)
    assert int(cache["pos"]) == S + 1 - (cfg.prefix_tokens if False else 0)
    assert np.all(np.isfinite(np.asarray(logits2, np.float32)))
