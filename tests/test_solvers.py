"""Local-solver layer: bit-identity of the generic solver round against
the seed implementation, the SOLVERS registry, solver-owned state
allocation, and the adaptive-lambda demo solver."""
import inspect

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import admm, mixing, sam, solvers
from repro.core.dfl import (ALGORITHMS, DFLConfig, consensus_distance,
                            init_state, make_train_round, simulate)
from repro.core.gossip import make_gossip, mask_and_renormalize
from repro.core.participation import ParticipationSpec

M, K = 4, 3


def _setup(seed=0):
    rng = np.random.default_rng(seed)
    params = {"w1": jnp.asarray(rng.normal(size=(5, 4)) / 3, jnp.float32),
              "b": jnp.asarray(rng.normal(size=(4,)), jnp.float32)}
    batches = {"x": jnp.asarray(rng.normal(size=(M, K, 8, 5)), jnp.float32),
               "y": jnp.asarray(rng.normal(size=(M, K, 8, 4)), jnp.float32)}

    def loss(p, batch, r):
        return jnp.mean((batch["x"] @ p["w1"] + p["b"] - batch["y"]) ** 2)

    return params, batches, loss


# ---------------------------------------------------------------------------
# Bit-identity: the generic solver scan vs the seed implementation
# ---------------------------------------------------------------------------
#
# ``_seed_round`` is a faithful copy of the pre-refactor
# ``dfl.py:client_local`` / ``round_fn`` pair — the hardcoded
# ``if is_admm / else`` fork over duals and momentum buffers, dense
# transport, identity codec.  Every ALGORITHMS entry must reproduce it
# bit for bit through the solver layer, at full participation AND on the
# masked path.

def _seed_round(cfg, loss_fn):
    masked = not cfg.participation.is_trivial
    rho = cfg.rho if cfg.algorithm in ("dfedadmm_sam", "dfedsam") else 0.0
    is_admm = cfg.algorithm.startswith("dfedadmm")
    loss_and_grad = sam.sam_value_and_grad(loss_fn, rho)

    def _tree_where(pred, a, b):
        return jax.tree.map(lambda x, y: jnp.where(pred, x, y), a, b)

    def client_local(anchor, dual, mom, batches_k, rng, lr_t,
                     active_i=None, n_steps=None):
        if is_admm:
            def body(carry, inp):
                params, rng_ = carry
                batch, k = inp if masked else (inp, None)
                rng_, sub = jax.random.split(rng_)
                l, g = loss_and_grad(params, batch, sub)
                new_params = admm.local_step(params, g, dual, anchor,
                                             lr=lr_t, lam=cfg.lam)
                if masked:
                    take = k < n_steps
                    new_params = _tree_where(take, new_params, params)
                    l = jnp.where(take, l, 0.0)
                return (new_params, rng_), l

            xs = (batches_k, jnp.arange(cfg.K)) if masked else batches_k
            (params_K, _), losses = jax.lax.scan(body, (anchor, rng), xs)
            new_dual = admm.dual_update(dual, params_K, anchor, lam=cfg.lam)
            z = admm.message(params_K, dual, lam=cfg.lam)
            if masked:
                new_dual = _tree_where(active_i, new_dual, dual)
                z = _tree_where(active_i, z, anchor)
                loss = jnp.mean(losses) * (
                    jnp.float32(cfg.K)
                    / jnp.maximum(n_steps.astype(jnp.float32), 1.0))
            else:
                loss = jnp.mean(losses)
            return params_K, new_dual, mom, z, loss

        wd = cfg.weight_decay

        def body(carry, inp):
            params, mom_, rng_ = carry
            batch, k = inp if masked else (inp, None)
            rng_, sub = jax.random.split(rng_)
            l, g = loss_and_grad(params, batch, sub)
            if wd:
                g = jax.tree.map(lambda gi, p: gi + wd * p, g, params)
            if cfg.algorithm == "dfedavgm":
                new_mom = jax.tree.map(
                    lambda mi, gi: (cfg.momentum * mi + gi).astype(mi.dtype),
                    mom_, g)
                upd = new_mom
            else:
                new_mom = mom_
                upd = g
            new_params = jax.tree.map(
                lambda p, u: (p.astype(jnp.float32)
                              - lr_t * u.astype(jnp.float32)).astype(p.dtype),
                params, upd)
            if masked:
                take = k < n_steps
                new_params = _tree_where(take, new_params, params)
                new_mom = _tree_where(take, new_mom, mom_)
                l = jnp.where(take, l, 0.0)
            return (new_params, new_mom, rng_), l

        steps = 1 if cfg.algorithm == "dpsgd" else cfg.K
        bk = jax.tree.map(lambda b: b[:steps], batches_k)
        xs = (bk, jnp.arange(steps)) if masked else bk
        (params_K, mom, _), losses = jax.lax.scan(body, (anchor, mom, rng), xs)
        if masked:
            done = jnp.minimum(n_steps, steps).astype(jnp.float32)
            loss = jnp.mean(losses) * (jnp.float32(steps)
                                       / jnp.maximum(done, 1.0))
        else:
            loss = jnp.mean(losses)
        return params_K, dual, mom, params_K, loss

    def round_fn(params, dual, momentum, state_rng, state_round, batches,
                 plan, active=None, steps=None):
        lr_t = cfg.lr * (cfg.lr_decay ** state_round.astype(jnp.float32))
        rngs = jax.vmap(lambda k: jax.random.fold_in(k, state_round))(
            state_rng)
        if masked:
            params_K, new_dual, new_mom, z, losses = jax.vmap(
                client_local, in_axes=(0, 0, 0, 0, 0, None, 0, 0)
            )(params, dual, momentum, batches, rngs, lr_t, active, steps)
        else:
            params_K, new_dual, new_mom, z, losses = jax.vmap(
                client_local, in_axes=(0, 0, 0, 0, 0, None)
            )(params, dual, momentum, batches, rngs, lr_t)
        new_params = mixing.mix_dense(plan, z)
        if masked:
            af = active.astype(jnp.float32)
            n_active = jnp.sum(af)
            mean_loss = jnp.mean(losses * af) * (
                jnp.float32(cfg.m) / jnp.maximum(n_active, 1.0))
            out = {"loss": jnp.where(n_active > 0, mean_loss, jnp.nan),
                   "lr": lr_t, "participation": jnp.mean(af)}
        else:
            out = {"loss": jnp.mean(losses), "lr": lr_t}
        out["consensus_sq"] = consensus_distance(new_params)
        out["dual_norm"] = sam.global_norm(new_dual)
        return new_params, new_dual, new_mom, out

    return jax.jit(round_fn)


def _solver_buffers(state, key, params):
    """The refactored state's dual/momentum, or seed-layout zeros."""
    if isinstance(state.solver, dict) and key in state.solver:
        return state.solver[key]
    return jax.tree.map(jnp.zeros_like, params)


@pytest.mark.parametrize("algo", ALGORITHMS)
def test_full_participation_bit_identical_to_seed(algo):
    params, batches, loss = _setup()
    cfg = DFLConfig(algorithm=algo, m=M, K=K, lam=0.2, topology="ring")
    spec = make_gossip("ring", M)
    plan = jnp.asarray(spec.matrix, jnp.float32)

    state = init_state(params, cfg, seed=0)
    rf = jax.jit(make_train_round(loss, cfg, spec=spec))
    st, met = rf(state, batches, plan)

    dual0 = jax.tree.map(jnp.zeros_like, state.params)
    mom0 = jax.tree.map(jnp.zeros_like, state.params)
    ref_params, ref_dual, ref_mom, ref_met = _seed_round(cfg, loss)(
        state.params, dual0, mom0, state.rng, jnp.zeros((), jnp.int32),
        batches, plan)

    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), ref_params, st.params)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)),
        ref_dual, _solver_buffers(st, "dual", state.params))
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)),
        ref_mom, _solver_buffers(st, "momentum", state.params))
    for k in ref_met:
        np.testing.assert_array_equal(np.asarray(ref_met[k]),
                                      np.asarray(met[k]), err_msg=k)


@pytest.mark.parametrize("algo", ALGORITHMS)
def test_masked_round_bit_identical_to_seed(algo):
    """The masked path (a real mask with an inactive client and a
    straggler) through the solver layer vs the seed masked machinery."""
    params, batches, loss = _setup()
    cfg = DFLConfig(algorithm=algo, m=M, K=K, lam=0.2, topology="ring",
                    participation=ParticipationSpec(mode="fraction", p=0.5))
    spec = make_gossip("ring", M)
    active = np.array([True, False, True, False])
    steps = np.array([K, 0, 1, 0], np.int32)
    plan = jnp.asarray(mask_and_renormalize(spec.matrix, active), jnp.float32)

    state = init_state(params, cfg, seed=0)
    rf = jax.jit(make_train_round(loss, cfg, spec=spec))
    st, met = rf(state, batches, plan, jnp.asarray(active),
                 jnp.asarray(steps))

    dual0 = jax.tree.map(jnp.zeros_like, state.params)
    mom0 = jax.tree.map(jnp.zeros_like, state.params)
    ref_params, ref_dual, ref_mom, ref_met = _seed_round(cfg, loss)(
        state.params, dual0, mom0, state.rng, jnp.zeros((), jnp.int32),
        batches, plan, jnp.asarray(active), jnp.asarray(steps))

    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), ref_params, st.params)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)),
        ref_dual, _solver_buffers(st, "dual", state.params))
    for k in ref_met:
        np.testing.assert_array_equal(np.asarray(ref_met[k]),
                                      np.asarray(met[k]), err_msg=k)


# ---------------------------------------------------------------------------
# SCAFFOLD: bit-checked NumPy reference for the control-variate round
# ---------------------------------------------------------------------------
#
# One gossip round of ``scaffold`` is pinned against a from-scratch NumPy
# transcription of SCAFFOLD option II (Karimireddy et al.) threaded
# through Definition-1 mixing.  Bitwise equality against a straight-line
# NumPy loop is only achievable when every product XLA may fuse into an
# FMA is exact, so the fixture is engineered around powers of two:
#
#   * lr = 0.125 and K = 4, so K*lr = 0.5 and 1/(K*lr) = 2.0 exactly;
#   * loss = mean((w - t)^2) over an 8-vector, so the gradient factor
#     2/8 = 0.25 is exact (XLA fuses ``g + (c_hat - c_i)`` into
#     fma(0.25, w - t, delta), which only equals the separately rounded
#     NumPy expression when the product is exact);
#   * the mixing plan is the two-term circulant W[i,i] = W[i,i+1] = 0.5,
#     doubly stochastic with power-of-two weights, so each mixed entry
#     is one exact-scaled addition regardless of contraction order.
#
# Any deviation in the update algebra — correction applied to the wrong
# operand, variates mixed before the c_i+ update, a masked client leaking
# a stale message — shows up as a bit difference, not an epsilon.

_SC_M, _SC_K, _SC_N = 4, 4, 8


def _scaffold_setup():
    rng = np.random.default_rng(0)
    params = {"w": jnp.asarray(rng.normal(size=(_SC_N,)), jnp.float32)}

    def batches_at(t):
        r = np.random.default_rng(50 + t)
        return {"t": jnp.asarray(r.normal(size=(_SC_M, _SC_K, _SC_N)),
                                 jnp.float32)}

    def loss(p, batch, r):
        return jnp.mean((p["w"] - batch["t"]) ** 2)

    W = np.zeros((_SC_M, _SC_M), np.float32)
    for i in range(_SC_M):
        W[i, i] = 0.5
        W[i, (i + 1) % _SC_M] = 0.5
    return params, batches_at, loss, W


def _scaffold_np_round(P, cv, ch, b, Wm, active=None, steps=None):
    """One NumPy SCAFFOLD round: (params, c_i, mixed track) -> same.

    ``P``/``cv``/``ch`` are (m, n) params, control variates, and the
    gossip-averaged variate estimate; ``b`` is (m, K, n) targets; ``Wm``
    the (already masked-and-renormalized) plan.
    """
    m, K = b.shape[0], b.shape[1]
    lr = np.float32(0.125)
    grad_scale = np.float32(2.0 / _SC_N)
    act = np.ones(m, bool) if active is None else np.asarray(active, bool)
    stp = np.full(m, K) if steps is None else np.asarray(steps)
    ys = P.copy()
    newcv = cv.copy()
    msg = ch.copy()       # an inactive client re-transmits nothing: the
    for i in range(m):    # identity plan row holds its buffered variate
        if not act[i]:
            continue
        y = P[i].copy()
        for k in range(int(stp[i])):
            g = grad_scale * (y - b[i, k])
            corrected = g + (ch[i] - cv[i])
            y = (y - lr * corrected).astype(np.float32)
        inv = np.float32(1.0) / (np.float32(K) * lr)
        d = ((P[i] - y) * inv).astype(np.float32)
        newcv[i] = (cv[i] - ch[i] + d).astype(np.float32)
        msg[i] = newcv[i]
        ys[i] = y
    mixedP = np.einsum("ij,jk->ik", Wm, ys).astype(np.float32)
    mixedT = np.einsum("ij,jk->ik", Wm, msg).astype(np.float32)
    return mixedP, newcv, mixedT


def test_scaffold_matches_numpy_reference_full():
    params, batches_at, loss, W = _scaffold_setup()
    cfg = DFLConfig(algorithm="scaffold", m=_SC_M, K=_SC_K, lr=0.125,
                    lr_decay=1.0, weight_decay=0.0, topology="ring")
    state = init_state(params, cfg, seed=0)
    rf = jax.jit(make_train_round(loss, cfg, spec=make_gossip("ring", _SC_M)))

    P = np.broadcast_to(np.asarray(params["w"])[None],
                        (_SC_M, _SC_N)).copy()
    cv = np.zeros((_SC_M, _SC_N), np.float32)
    ch = np.zeros((_SC_M, _SC_N), np.float32)
    for t in range(3):
        state, met = rf(state, batches_at(t), jnp.asarray(W))
        P, cv, ch = _scaffold_np_round(
            P, cv, ch, np.asarray(batches_at(t)["t"]), W)
        assert np.isfinite(float(met["loss"]))
    np.testing.assert_array_equal(np.asarray(state.params["w"]), P)
    np.testing.assert_array_equal(np.asarray(state.solver["cv"]["w"]), cv)
    np.testing.assert_array_equal(np.asarray(state.comm["track"]["w"]), ch)


def test_scaffold_matches_numpy_reference_masked():
    """Partial participation: one inactive client, one straggler.  The
    inactive client's params, c_i, AND buffered variate estimate must
    all hold bit-exactly; the straggler's K-step normalization
    1/(K*lr) still uses the full K (option II), not its step count."""
    params, batches_at, loss, W = _scaffold_setup()
    cfg = DFLConfig(algorithm="scaffold", m=_SC_M, K=_SC_K, lr=0.125,
                    lr_decay=1.0, weight_decay=0.0, topology="ring",
                    participation=ParticipationSpec(mode="uniform", p=0.75))
    state = init_state(params, cfg, seed=0)
    rf = jax.jit(make_train_round(loss, cfg, spec=make_gossip("ring", _SC_M)))

    active = np.array([True, False, True, True])
    steps = np.array([_SC_K, 0, 2, _SC_K], np.int32)
    Wm = mask_and_renormalize(W, active)
    P = np.broadcast_to(np.asarray(params["w"])[None],
                        (_SC_M, _SC_N)).copy()
    cv = np.zeros((_SC_M, _SC_N), np.float32)
    ch = np.zeros((_SC_M, _SC_N), np.float32)
    for t in range(3):
        state, _ = rf(state, batches_at(t), jnp.asarray(Wm),
                      jnp.asarray(active), jnp.asarray(steps))
        P, cv, ch = _scaffold_np_round(
            P, cv, ch, np.asarray(batches_at(t)["t"]), Wm, active, steps)
    np.testing.assert_array_equal(np.asarray(state.params["w"]), P)
    np.testing.assert_array_equal(np.asarray(state.solver["cv"]["w"]), cv)
    np.testing.assert_array_equal(np.asarray(state.comm["track"]["w"]), ch)


# ---------------------------------------------------------------------------
# Solver-owned state: no dead parameter-sized buffers
# ---------------------------------------------------------------------------

def test_init_state_allocates_only_what_the_solver_uses():
    """Regression for the seed over-allocation: every algorithm used to
    carry BOTH a dual and a momentum tree of full (m, ...) zeros."""
    params, _, _ = _setup()

    st = init_state(params, DFLConfig(algorithm="dfedadmm", m=M, K=K))
    assert set(st.solver) == {"dual"}          # no momentum buffer

    st = init_state(params, DFLConfig(algorithm="dfedavg", m=M, K=K))
    assert st.solver is None                   # no dual, no momentum
    # the whole state is params + rng + round — nothing else allocated
    assert len(jax.tree.leaves(st)) == len(jax.tree.leaves(st.params)) + 2

    st = init_state(params, DFLConfig(algorithm="dfedavgm", m=M, K=K))
    assert set(st.solver) == {"momentum"}      # no dual buffer

    st = init_state(params, DFLConfig(algorithm="dfedadmm_adaptive",
                                      m=M, K=K))
    assert set(st.solver) == {"dual", "lam_scale"}
    assert st.solver["lam_scale"].shape == (M,)

    # variance-reduction solvers: one param-shaped solver buffer each,
    # plus the gossip-carried tracking slot in comm (NOT solver state)
    st = init_state(params, DFLConfig(algorithm="scaffold", m=M, K=K))
    assert set(st.solver) == {"cv"}
    assert set(st.comm) == {"track"}

    st = init_state(params, DFLConfig(algorithm="dfedtrack", m=M, K=K))
    assert set(st.solver) == {"d_prev"}
    assert set(st.comm) == {"track"}


def test_deprecated_dual_momentum_properties_removed():
    """The deprecation window is closed: solver state is reachable only
    through ``state.solver[...]`` — the old properties raise."""
    params, _, _ = _setup()
    st = init_state(params, DFLConfig(algorithm="dfedadmm", m=M, K=K))
    with pytest.raises(AttributeError):
        st.dual
    with pytest.raises(AttributeError):
        st.momentum


# ---------------------------------------------------------------------------
# Registry: a solver registered from user code runs end-to-end
# ---------------------------------------------------------------------------

class _ToySignSGD(solvers.LocalSolver):
    """sign-SGD with a per-client step counter — exercises non-param-
    shaped solver state through the full round loop."""

    def init_state(self, cfg, stacked_params):
        m = jax.tree.leaves(stacked_params)[0].shape[0]
        return {"count": jnp.zeros((m,), jnp.int32)}

    def step(self, params, grads, state, anchor, lr):
        new_params = jax.tree.map(
            lambda p, g: (p.astype(jnp.float32)
                          - lr * jnp.sign(g.astype(jnp.float32))
                          ).astype(p.dtype), params, grads)
        return new_params, {"count": state["count"] + 1}


def test_registered_toy_solver_runs_through_simulate():
    solvers.register_solver("toy_signsgd", lambda cfg: _ToySignSGD(),
                            overwrite=True)
    try:
        params, _, loss = _setup()

        def sampler(t):
            r = np.random.default_rng(100 + t)
            return {"x": jnp.asarray(r.normal(size=(M, K, 8, 5)),
                                     jnp.float32),
                    "y": jnp.asarray(r.normal(size=(M, K, 8, 4)),
                                     jnp.float32)}

        cfg = DFLConfig(algorithm="toy_signsgd", m=M, K=K, lr=0.01,
                        topology="ring")
        state, hist = simulate(loss, None, params, cfg, sampler, rounds=3)
        assert np.isfinite(hist["loss"]).all()
        # the counter advanced K steps per round on every client
        np.testing.assert_array_equal(np.asarray(state.solver["count"]),
                                      np.full((M,), 3 * K, np.int32))
        # dual_norm telemetry degrades gracefully for dual-less solvers
        assert hist["dual_norm"] == [0.0] * 3
    finally:
        del solvers.SOLVERS["toy_signsgd"]     # keep the registry hermetic


def test_unknown_algorithm_lists_registry():
    with pytest.raises(ValueError, match="registered DFL solvers"):
        DFLConfig(algorithm="smoke-signals")
    # CFL-scoped solvers are not silently runnable on the gossip round —
    # and the error must say which registry WAS searched, so a user who
    # typo'd the scope sees the fix in the message
    with pytest.raises(ValueError, match="registered DFL solvers"):
        DFLConfig(algorithm="fedavg")


# ---------------------------------------------------------------------------
# Adaptive-lambda demo solver
# ---------------------------------------------------------------------------

def test_adaptive_admm_learns_and_keeps_lam_bounded():
    params, _, loss = _setup()

    def sampler(t):
        r = np.random.default_rng(100 + t)
        return {"x": jnp.asarray(r.normal(size=(M, K, 8, 5)), jnp.float32),
                "y": jnp.asarray(r.normal(size=(M, K, 8, 4)), jnp.float32)}

    cfg = DFLConfig(algorithm="dfedadmm_adaptive", m=M, K=K, lam=0.2,
                    topology="ring")
    state, hist = simulate(loss, None, params, cfg, sampler, rounds=8)
    assert hist["loss"][-1] < hist["loss"][0]
    scale = np.asarray(state.solver["lam_scale"])
    bound = solvers.AdaptiveADMMSolver.BOUND
    assert ((scale >= 1.0 / bound) & (scale <= bound)).all()
    assert np.isfinite(hist["dual_norm"]).all()


def test_adaptive_admm_matches_fixed_lam_until_rebalance():
    """With an untriggered rebalance margin the adaptive solver IS
    DFedADMM: lam_scale stays 1 and the round is bit-identical."""
    params, batches, loss = _setup()
    spec = make_gossip("ring", M)
    plan = jnp.asarray(spec.matrix, jnp.float32)
    outs = {}
    for algo in ("dfedadmm", "dfedadmm_adaptive"):
        cfg = DFLConfig(algorithm=algo, m=M, K=K, lam=0.2, topology="ring")
        state = init_state(params, cfg, seed=0)
        rf = jax.jit(make_train_round(loss, cfg, spec=spec))
        st, _ = rf(state, batches, plan)
        outs[algo] = st
    adaptive = outs["dfedadmm_adaptive"]
    scale = np.asarray(adaptive.solver["lam_scale"])
    if (scale == 1.0).all():                   # no rebalance fired round 0
        jax.tree.map(lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b)),
            outs["dfedadmm"].params, adaptive.params)


# ---------------------------------------------------------------------------
# CFL reuse + kernel routing
# ---------------------------------------------------------------------------

def test_baselines_has_no_duplicated_inner_loops():
    """Acceptance: the ADMM/SGD/SAM inner-loop bodies live in solvers.py
    only — baselines.py drives solver objects instead of re-implementing
    them."""
    import repro.core.baselines as baselines
    src = inspect.getsource(baselines)
    for needle in ("local_step", "dual_update", "admm.message",
                   "weight_decay * p", "momentum * mi"):
        assert needle not in src, needle
    assert "solvers_lib.make_solver" in src


def test_cfl_solver_states():
    from repro.core import CFLConfig, init_cfl_state
    params, _, _ = _setup()
    st = init_cfl_state(params, CFLConfig(algorithm="fedavg", m=8))
    assert st.solver is None
    st = init_cfl_state(params, CFLConfig(algorithm="fedpd", m=8))
    assert set(st.solver) == {"dual"}
    assert jax.tree.leaves(st.solver["dual"])[0].shape[0] == 8
    with pytest.raises(ValueError, match="registered CFL solvers"):
        CFLConfig(algorithm="dfedadmm")


def test_sgd_solver_kernel_path_matches_jnp():
    params, _, _ = _setup()
    rng = np.random.default_rng(3)
    g = jax.tree.map(
        lambda p: jnp.asarray(rng.normal(size=p.shape), jnp.float32), params)
    ref_solver = solvers.SGDSolver(weight_decay=5e-4)
    ker_solver = solvers.SGDSolver(weight_decay=5e-4, use_kernel=True)
    p_ref, _ = ref_solver.step(params, g, None, params, 0.1)
    p_ker, _ = ker_solver.step(params, g, None, params, 0.1)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-7), p_ref, p_ker)
