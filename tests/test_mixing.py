"""Mixing execution: dense einsum vs collective_permute equivalence and
conservation properties."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gossip, mixing

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

# the shard_map/ppermute substrate needs jax.sharding.AxisType (jax >= 0.5)
_HAS_AXIS_TYPE = hasattr(jax.sharding, "AxisType")


def test_dense_mix_matches_matmul():
    spec = gossip.make_gossip("exp", 8)
    z = {"a": jnp.asarray(np.random.default_rng(0).normal(size=(8, 3, 4)),
                          jnp.float32)}
    out = mixing.mix_dense(spec.matrix, z)
    ref = np.einsum("ij,jkl->ikl", spec.matrix, np.asarray(z["a"]))
    np.testing.assert_allclose(out["a"], ref, rtol=1e-5)


def test_dense_mix_preserves_mean():
    spec = gossip.make_gossip("ring", 10)
    z = jnp.asarray(np.random.default_rng(1).normal(size=(10, 7)), jnp.float32)
    out = mixing.mix_dense(spec.matrix, {"p": z})["p"]
    np.testing.assert_allclose(np.mean(out, 0), np.mean(np.asarray(z), 0),
                               atol=1e-6)


def test_dense_mix_bf16_contracts_in_f32():
    """Numerics regression: bf16 leaves must mix against the f32 matrix
    with f32 accumulation — casting W down to the leaf dtype de-normalizes
    its rows (a bf16 gossip matrix is no longer doubly stochastic to
    machine precision), silently drifting the client-mean every round."""
    m = 16
    spec = gossip.make_gossip("exp", m)
    rng = np.random.default_rng(3)
    z = jnp.asarray(rng.normal(size=(m, 257)) * 100.0, jnp.bfloat16)

    # the contraction itself must be f32 x f32 -> f32: the only casts in
    # the jaxpr are the leaf up-cast and the final down-cast, never a
    # conversion of the matrix to bf16
    jaxpr = str(jax.make_jaxpr(
        lambda zz: mixing.mix_dense(spec.matrix, zz))({"p": z}))
    assert "new_dtype=bfloat16" in jaxpr          # only the output cast
    assert jaxpr.count("new_dtype=bfloat16") == 1
    assert "preferred_element_type=float32" in jaxpr

    # numerically: every element within one bf16 rounding of the exact
    # f64 mix, and the client-mean preserved to that same single-rounding
    # tolerance (no accumulated row-sum bias)
    out = mixing.mix_dense(spec.matrix, {"p": z})["p"]
    assert out.dtype == jnp.bfloat16
    exact = spec.matrix @ np.asarray(z, np.float64)
    np.testing.assert_allclose(np.asarray(out, np.float32), exact,
                               rtol=2 ** -8, atol=1e-6)
    mean_err = np.abs(np.mean(np.asarray(out, np.float32), 0)
                      - exact.mean(0))
    tol = np.abs(exact).max(0) * 2 ** -8 + 1e-6
    assert (mean_err <= tol).all()


def test_full_topology_mix_is_average():
    spec = gossip.make_gossip("full", 6)
    z = jnp.asarray(np.random.default_rng(2).normal(size=(6, 5)), jnp.float32)
    out = mixing.mix_dense(spec.matrix, {"p": z})["p"]
    np.testing.assert_allclose(out, np.broadcast_to(np.mean(np.asarray(z), 0),
                                                    (6, 5)), atol=1e-5)


def test_non_circulant_ppermute_raises():
    spec = gossip.make_gossip("random", 8, degree=3, seed=1)
    if spec.is_circulant():
        pytest.skip("random draw happened to be circulant")
    with pytest.raises(ValueError):
        mixing._circulant_pattern(spec)


_PPERMUTE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import AxisType
from repro.core import gossip, mixing

mesh = jax.make_mesh((8,), ("data",), axis_types=(AxisType.Auto,))
for topo in ("ring", "exp", "full"):
    spec = gossip.make_gossip(topo, 8)
    z = {"a": jnp.asarray(np.random.default_rng(0).normal(size=(8, 4, 6)),
                          jnp.float32)}
    dense = mixing.mix_dense(spec.matrix, z)
    pp = mixing.mix_ppermute(z, spec, mesh, "data")
    np.testing.assert_allclose(np.asarray(pp["a"]), np.asarray(dense["a"]),
                               rtol=1e-5, atol=1e-6)
print("PPERMUTE_OK")
"""


@pytest.mark.skipif(not _HAS_AXIS_TYPE,
                    reason="jax.sharding.AxisType unavailable in this jax")
def test_ppermute_equals_dense_subprocess():
    """ppermute mixing == dense W mixing on 8 fake devices."""
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _PPERMUTE_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=300)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "PPERMUTE_OK" in r.stdout


_PUSHSUM_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import AxisType
from repro.core import comm, gossip, mixing

mesh = jax.make_mesh((8,), ("data",), axis_types=(AxisType.Auto,))
spec = gossip.make_gossip("dring", 8)
ps = gossip.GossipSpec(topology=spec.topology,
                       matrix=gossip.as_column_stochastic(spec.matrix),
                       psi=spec.psi)
z = {"a": jnp.asarray(np.random.default_rng(0).normal(size=(8, 4, 6)),
                      jnp.float32),
     "b": jnp.asarray(np.random.default_rng(1).normal(size=(8, 3)),
                      jnp.float32)}
pi = jnp.full((8,), 1.0 / 8, jnp.float32)

# meshless reference: the dense column-stochastic push-sum step
dense = comm.PushSumTransport()
ref, ref_pi = dense.mix(z, jnp.asarray(ps.matrix), aux=pi)

out, out_pi = mixing.mix_pushsum_ppermute(z, pi, ps, mesh, "data")
np.testing.assert_allclose(np.asarray(out_pi), np.asarray(ref_pi),
                           rtol=1e-6, atol=1e-7)
for k in z:
    np.testing.assert_allclose(np.asarray(out[k]), np.asarray(ref[k]),
                               rtol=1e-5, atol=1e-6)

# weighted sum conservation (the push-sum invariant) over several rounds
zz, pp = z, pi
for _ in range(5):
    zz, pp = mixing.mix_pushsum_ppermute(zz, pp, ps, mesh, "data")
w0 = np.sum(np.asarray(pi)[:, None, None] * np.asarray(z["a"]), 0)
wt = np.sum(np.asarray(pp)[:, None, None] * np.asarray(zz["a"]), 0)
np.testing.assert_allclose(wt, w0, rtol=1e-4, atol=1e-5)
print("PUSHSUM_PPERMUTE_OK")
"""


@pytest.mark.skipif(not _HAS_AXIS_TYPE,
                    reason="jax.sharding.AxisType unavailable in this jax")
def test_pushsum_ppermute_equals_dense_subprocess():
    """On-mesh push-sum (directed permutes + the extra pi permute chain)
    == the dense column-stochastic push-sum step on 8 fake devices."""
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _PUSHSUM_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=300)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "PUSHSUM_PPERMUTE_OK" in r.stdout
