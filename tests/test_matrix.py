"""Cross-layer correctness matrix for the variance-reduction family.

Every new solver (scaffold / dfedtrack / dfedadmm_adaptive) is driven
through every transport x codec x execution x participation regime the
system composes, in one fixture-driven file:

    solver     x {dense, ppermute, pushsum, hier}
               x {identity, int8, fp8}
               x {sync, async}
               x {full, masked, cohort}

and each cell asserts the same three invariant groups:

  * state shapes — the solver allocates exactly its declared buffers,
    stacked (m, ...), and tracking solvers carry exactly one
    gossip-slot ``comm["track"]`` of param shape;
  * Definition-1 — the mixing plan the run was built on is doubly
    stochastic (row- AND column-stochastic; column-stochastic for the
    push-sum de-biased path), so the population mean is conserved;
  * telemetry — losses finite, lr positive, wire bytes counted every
    round, participation / staleness inside their contracts.

A representative subset covering every axis value runs in the fast
tier; the exhaustive 216-cell product runs under ``-m slow``.
"""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import comm as comm_lib, gossip, solvers
from repro.core.dfl import DFLConfig, simulate
from repro.core.participation import ParticipationSpec

M, K = 4, 2

SOLVERS = ["scaffold", "dfedtrack", "dfedadmm_adaptive"]
# transport -> the topology it is defined over (push-sum needs a
# directed graph; the rest ride the symmetric ring)
TRANSPORTS = [("dense", "ring"), ("ppermute", "ring"),
              ("pushsum", "dring"), ("hier", "ring")]
CODECS = ["identity", "int8", "fp8"]
EXECUTIONS = ["sync", "async"]
REGIMES = ["full", "masked", "cohort"]

# what each solver owns, and what rides the gossip slot
SOLVER_STATE_KEYS = {"scaffold": {"cv"},
                     "dfedtrack": {"d_prev"},
                     "dfedadmm_adaptive": {"dual", "lam_scale"}}
TRACKING = {"scaffold", "dfedtrack"}


def _params():
    return {"w": jnp.zeros((3, 2), jnp.float32),
            "b": jnp.zeros((2,), jnp.float32)}


def _loss(p, batch, r):
    x, y = batch
    return jnp.mean((x @ p["w"] + p["b"] - y) ** 2)


def _sampler(m, seed=0):
    def sample(t):
        rng = np.random.default_rng((seed, t))
        x = rng.standard_normal((m, K, 4, 3)).astype(np.float32)
        y = np.tanh(x @ rng.standard_normal((3, 2)).astype(np.float32))
        return (jnp.asarray(x), jnp.asarray(y.astype(np.float32)))
    return sample


def _config(algo, transport, topology, codec, execution, regime):
    kw = dict(algorithm=algo, m=M, K=K, lr=0.05, topology=topology,
              transport=transport, codec=codec)
    if transport == "hier":
        kw["clusters"] = 2
    if execution == "async":
        kw.update(network="wan-lan", execution="async", tick_s=0.02,
                  max_staleness=3)
    if regime == "masked":
        kw["participation"] = ParticipationSpec(mode="fraction", p=0.5,
                                                seed=3)
    elif regime == "cohort":
        kw["n_virtual"] = 2 * M
    return DFLConfig(**kw)


def _run(algo, transport, topology, codec, execution, regime, rounds=2):
    cfg = _config(algo, transport, topology, codec, execution, regime)
    state, hist = simulate(_loss, None, _params(), cfg, _sampler(M),
                           rounds=rounds, seed=1)
    return cfg, state, hist


def _assert_invariants(cfg, state, hist, algo, transport, topology,
                       execution, regime, rounds):
    params = _params()
    # --- state shapes ----------------------------------------------------
    for name, leaf in params.items():
        got = state.params[name]
        assert got.shape == (M,) + leaf.shape, (name, got.shape)
        assert got.dtype == leaf.dtype
    assert set(state.solver) == SOLVER_STATE_KEYS[algo]
    for key in SOLVER_STATE_KEYS[algo] - {"lam_scale"}:
        for name, leaf in params.items():
            assert state.solver[key][name].shape == (M,) + leaf.shape
    if "lam_scale" in SOLVER_STATE_KEYS[algo]:
        assert state.solver["lam_scale"].shape == (M,)

    comm = state.comm or {}
    if algo in TRACKING:
        assert "track" in comm, "tracking solver lost its gossip slot"
        for name, leaf in params.items():
            t = comm["track"][name]
            assert t.shape == (M,) + leaf.shape
            assert bool(jnp.isfinite(t).all())
    else:
        assert "track" not in comm
    if transport == "pushsum":
        pi = np.asarray(comm["ps_weight"])
        assert (pi > 0).all()
        np.testing.assert_allclose(pi.sum(), 1.0, atol=1e-5)
    if comm_lib.make_codec(cfg).stateful:
        for leaf in jax.tree.leaves(comm["residual"]):
            assert bool(jnp.isfinite(leaf).all())

    # --- Definition-1 on the plan the run was built over -----------------
    spec = gossip.make_gossip(topology, M)
    if transport == "pushsum":
        np.testing.assert_allclose(spec.matrix.sum(axis=0), 1.0,
                                   atol=1e-6)  # column-stochastic
    elif transport == "hier":
        plan = comm_lib.make_transport(cfg).prepare(None)
        for tier in ("intra", "inter"):
            gossip.validate_gossip_matrix(np.asarray(plan[tier]))
    else:
        gossip.validate_gossip_matrix(spec.matrix)

    # --- telemetry --------------------------------------------------------
    assert len(hist["loss"]) == rounds
    loss = np.asarray(hist["loss"])
    if execution == "async" and regime == "cohort":
        # a tick with no ready cohort measures nothing (NaN by contract)
        ticked = np.asarray(hist["ticked"])
        assert np.isfinite(loss[ticked > 0]).all()
    else:
        assert np.isfinite(loss).all()
    assert (np.asarray(hist["lr"]) > 0).all()
    assert len(hist["wire_bytes"]) == rounds
    assert all(wb >= 0 for wb in hist["wire_bytes"])
    assert any(wb > 0 for wb in hist["wire_bytes"])
    if regime == "masked" and execution == "sync":
        part = np.asarray(hist["participation"])
        assert ((part >= 0.0) & (part <= 1.0)).all()
    if execution == "async":
        assert all(0.0 <= f <= 1.0 for f in hist["ticked"])
        if regime != "cohort":
            # the virtualized async loop paces by cohort readiness, not
            # per-tick staleness — only the device-resident engine
            # reports the staleness telemetry
            assert all(0 <= s <= cfg.max_staleness
                       for s in hist["staleness"])


# representative diagonal: every axis value appears at least once per
# invariant group, one cell per line
FAST_CELLS = [
    ("scaffold", "dense", "identity", "sync", "full"),
    ("scaffold", "ppermute", "int8", "sync", "masked"),
    ("scaffold", "pushsum", "identity", "async", "full"),
    ("dfedtrack", "dense", "fp8", "async", "masked"),
    ("dfedtrack", "hier", "identity", "sync", "cohort"),
    ("dfedtrack", "pushsum", "int8", "sync", "full"),
    ("dfedadmm_adaptive", "dense", "int8", "async", "cohort"),
    ("dfedadmm_adaptive", "hier", "fp8", "sync", "masked"),
    ("dfedadmm_adaptive", "ppermute", "identity", "sync", "full"),
]

_TOPO = dict(TRANSPORTS)


@pytest.mark.parametrize("algo,transport,codec,execution,regime", FAST_CELLS)
def test_matrix_fast(algo, transport, codec, execution, regime):
    topology = _TOPO[transport]
    cfg, state, hist = _run(algo, transport, topology, codec, execution,
                            regime, rounds=2)
    _assert_invariants(cfg, state, hist, algo, transport, topology,
                       execution, regime, rounds=2)


FULL_CELLS = [c for c in itertools.product(SOLVERS,
                                           [t for t, _ in TRANSPORTS],
                                           CODECS, EXECUTIONS, REGIMES)
              if c not in FAST_CELLS]


@pytest.mark.slow
@pytest.mark.parametrize("algo,transport,codec,execution,regime", FULL_CELLS)
def test_matrix_full(algo, transport, codec, execution, regime):
    topology = _TOPO[transport]
    cfg, state, hist = _run(algo, transport, topology, codec, execution,
                            regime, rounds=1)
    _assert_invariants(cfg, state, hist, algo, transport, topology,
                       execution, regime, rounds=1)


def test_matrix_covers_every_axis_value():
    """The fast diagonal really touches every value of every axis."""
    for i, values in enumerate([SOLVERS, [t for t, _ in TRANSPORTS],
                                CODECS, EXECUTIONS, REGIMES]):
        seen = {cell[i] for cell in FAST_CELLS}
        assert seen == set(values), (i, seen)
