"""Partition-rule unit tests (pure spec logic, no devices needed)."""
from jax.sharding import PartitionSpec as P

from repro.configs import get_bundle, get_model_config
from repro.models.model import param_shapes
from repro.sharding import partition


def test_dense_param_specs():
    cfg = get_model_config("llama3-8b")
    par = get_bundle("llama3-8b").parallel
    shapes = param_shapes(cfg)
    specs = partition.param_specs(shapes, cfg, par)
    assert specs["embed"] == P("model", None)
    assert specs["lm_head"] == P(None, "model")
    assert specs["layers"]["attn"]["wq"] == P(None, None, "model")
    assert specs["layers"]["attn"]["wo"] == P(None, "model", None)
    assert specs["layers"]["mlp"]["w_down"] == P(None, "model", None)


def test_stacked_client_prepends_axis():
    cfg = get_model_config("llama3-8b")
    par = get_bundle("llama3-8b").parallel
    shapes = param_shapes(cfg)
    specs = partition.param_specs(shapes, cfg, par, stacked_client=True)
    assert specs["layers"]["attn"]["wq"] == P("data", None, None, "model")
    assert specs["embed"] == P("data", "model", None)


def test_moe_expert_vs_tensor_sharding():
    mx = get_model_config("mixtral-8x7b")
    q = get_model_config("qwen3-moe-235b-a22b")
    sp_mx = partition.param_specs(param_shapes(mx), mx,
                                  get_bundle("mixtral-8x7b").parallel)
    sp_q = partition.param_specs(param_shapes(q), q,
                                 get_bundle("qwen3-moe-235b-a22b").parallel)
    # mixtral: shard d_ff; qwen3: shard the expert axis
    assert sp_mx["layers"]["moe"]["w_gate"] == P(None, None, None, "model")
    assert sp_q["layers"]["moe"]["w_gate"] == P(None, "model", None, None)
    assert sp_mx["layers"]["moe"]["w_down"] == P(None, None, "model", None)


def test_mamba_specs():
    cfg = get_model_config("falcon-mamba-7b")
    par = get_bundle("falcon-mamba-7b").parallel
    specs = partition.param_specs(param_shapes(cfg), cfg, par)
    mixer = specs["layers"]["mixer"]
    assert mixer["in_proj"] == P(None, None, "model")
    assert mixer["out_proj"] == P(None, "model", None)
    assert mixer["A_log"] == P(None, "model", None)


def test_fsdp_axis_threads_through():
    cfg = get_model_config("llama3-405b")
    import dataclasses
    par = dataclasses.replace(get_bundle("llama3-405b").parallel,
                              fsdp_axis="data", client_axis="pod")
    specs = partition.param_specs(param_shapes(cfg), cfg, par,
                                  stacked_client=True)
    assert specs["layers"]["attn"]["wq"] == P("pod", None, "data", "model")
    assert specs["layers"]["mlp"]["w_down"] == P("pod", None, "model", "data")


def test_decode_specs_long_context():
    cfg = get_model_config("gemma3-12b")
    bundle = get_bundle("gemma3-12b")
    from repro.configs import input_specs
    sds = input_specs(cfg, bundle.parallel, "long_500k")
    specs = partition.decode_specs(sds, cfg, bundle.parallel, False,
                                   long_context=True)
    assert specs["cache"]["k"] == P(None, None, "data", None, None)
    assert specs["token"] == P(None)


def test_hybrid_shared_attn_specs():
    cfg = get_model_config("zamba2-1.2b")
    par = get_bundle("zamba2-1.2b").parallel
    specs = partition.param_specs(param_shapes(cfg), cfg, par)
    assert specs["shared_attn"]["attn"]["wq"] == P(None, "model")
    assert specs["shared_attn"]["mlp"]["w_down"] == P("model", None)
