"""Adversarial & privacy layer (repro.core.threat): attack injection,
robust transport-level aggregation, the DP wire codec, and the
bit-identity guarantee that an empty threat + robust="mean" IS the
unthreatened round."""
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (DFLConfig, DPCodec, KrumAggregator, MeanAggregator,
                        MedianAggregator, ThreatSpec, TrimmedMeanAggregator,
                        adversary_mask, aggregator_names, attack_names,
                        make_attack, register_aggregator, register_attack,
                        simulate, solver_names)
from repro.core.threat import make_aggregator

sys.path.insert(0, os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..")))   # "benchmarks" package


def _toy_problem(m=8, K=3, seed=0):
    def loss_fn(p, batch, rng):
        pred = batch["x"] @ p["w"]
        return jnp.mean((pred - batch["y"]) ** 2)

    rng = np.random.default_rng(seed)
    params = {"w": jnp.asarray(rng.normal(size=(6, 1)), jnp.float32)}

    def sampler(t):
        r = np.random.default_rng((seed, t))
        x = r.normal(size=(m, K, 16, 6)).astype(np.float32)
        y = x.sum(-1, keepdims=True).astype(np.float32)
        return {"x": jnp.asarray(x), "y": jnp.asarray(y)}

    return loss_fn, params, sampler


def _stacked(m=6, d=5, seed=0):
    rng = np.random.default_rng(seed)
    return {"a": jnp.asarray(rng.normal(size=(m, d)), jnp.float32),
            "b": jnp.asarray(rng.normal(size=(m, 2, 3)), jnp.float32)}


# ---------------------------------------------------------------------------
# ThreatSpec + adversary selection
# ---------------------------------------------------------------------------

def test_threat_spec_validation():
    with pytest.raises(ValueError, match="attack"):
        ThreatSpec(attack="nope")
    with pytest.raises(ValueError, match="frac"):
        ThreatSpec(frac=1.5)
    with pytest.raises(ValueError, match="scale"):
        ThreatSpec(scale=float("inf"))
    assert ThreatSpec(frac=0.0).is_trivial
    assert not ThreatSpec(frac=0.2).is_trivial
    assert ThreatSpec(frac=0.2).n_adversaries(16) == 3


def test_adversary_mask_seeded_and_sized():
    spec = ThreatSpec(attack="signflip", frac=0.25, seed=7)
    m1 = adversary_mask(spec, 16)
    m2 = adversary_mask(spec, 16)
    np.testing.assert_array_equal(m1, m2)            # persistent set
    assert m1.sum() == 4
    assert adversary_mask(ThreatSpec(frac=0.0), 16).sum() == 0
    m3 = adversary_mask(ThreatSpec(attack="signflip", frac=0.25, seed=8), 16)
    assert not np.array_equal(m1, m3)                # seed moves the set


# ---------------------------------------------------------------------------
# Attacks: adversary rows perturbed, honest rows bit-identical
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(attack_names()))
def test_attacks_gate_honest_rows_bitwise(name):
    z = _stacked()
    adv = jnp.asarray([True, False, True, False, False, False])
    atk = make_attack(ThreatSpec(attack=name, frac=0.3, scale=2.0))
    out = atk.perturb(z, adv, jax.random.PRNGKey(0))
    for k in z:
        np.testing.assert_array_equal(np.asarray(out[k])[~np.asarray(adv)],
                                      np.asarray(z[k])[~np.asarray(adv)])
    # the adversary rows actually changed (zero on nonzero data changes)
    changed = any(
        not np.array_equal(np.asarray(out[k])[np.asarray(adv)],
                           np.asarray(z[k])[np.asarray(adv)]) for k in z)
    assert changed


def test_signflip_and_zero_semantics():
    z = _stacked()
    adv = jnp.asarray([True, False, False, False, False, True])
    flip = make_attack(ThreatSpec(attack="signflip", frac=0.3, scale=3.0))
    out = flip.perturb(z, adv, jax.random.PRNGKey(0))
    np.testing.assert_allclose(np.asarray(out["a"])[0],
                               -3.0 * np.asarray(z["a"])[0], rtol=1e-6)
    zero = make_attack(ThreatSpec(attack="zero", frac=0.3))
    out = zero.perturb(z, adv, jax.random.PRNGKey(0))
    assert (np.asarray(out["b"])[5] == 0.0).all()


def test_collude_sends_one_agreed_model():
    z = _stacked()
    adv = jnp.asarray([True, True, False, True, False, False])
    atk = make_attack(ThreatSpec(attack="collude", frac=0.5, scale=2.0))
    out = atk.perturb(z, adv, jax.random.PRNGKey(0))
    a = np.asarray(out["a"])
    np.testing.assert_array_equal(a[0], a[1])
    np.testing.assert_array_equal(a[0], a[3])
    mu = np.asarray(z["a"])[[0, 1, 3]].mean(0)
    np.testing.assert_allclose(a[0], 2.0 * mu, rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# Robust aggregators
# ---------------------------------------------------------------------------

_AGGS = [MeanAggregator(), TrimmedMeanAggregator(0.25), MedianAggregator(),
         KrumAggregator(0.25)]


@pytest.mark.parametrize("agg", _AGGS, ids=lambda a: a.name)
def test_identity_plan_rows_pass_through_bitwise(agg):
    """Frozen clients sit on identity rows in every masked/async plan —
    every aggregator must hand their own message straight back."""
    m = 5
    z = _stacked(m=m)
    w = np.eye(m, dtype=np.float32)
    out = agg.aggregate(z, jnp.asarray(w))
    for k in z:
        np.testing.assert_array_equal(np.asarray(out[k]), np.asarray(z[k]))


@pytest.mark.parametrize("agg", _AGGS[1:], ids=lambda a: a.name)
def test_robust_aggregators_reject_one_outlier(agg):
    """Full-support neighbourhood, one huge outlier: the robust estimate
    stays inside the honest values' range (mean would not)."""
    m = 6
    rng = np.random.default_rng(0)
    honest = rng.normal(size=(m, 4)).astype(np.float32)
    vals = honest.copy()
    vals[2] = 1e4                                     # Byzantine row
    z = {"a": jnp.asarray(vals)}
    w = jnp.full((m, m), 1.0 / m, dtype=jnp.float32)
    out = np.asarray(agg.aggregate(z, w)["a"])
    hmin = honest[[i for i in range(m) if i != 2]].min()
    hmax = honest[[i for i in range(m) if i != 2]].max()
    assert (out >= hmin - 1e-5).all() and (out <= hmax + 1e-5).all()


def test_mean_aggregator_matches_mix_dense():
    from repro.core import mixing
    m = 6
    z = _stacked(m=m)
    rng = np.random.default_rng(1)
    w = rng.random((m, m)).astype(np.float32)
    w /= w.sum(1, keepdims=True)                      # row-stochastic
    out = MeanAggregator().aggregate(z, jnp.asarray(w))
    ref = mixing.mix_dense(jnp.asarray(w), z)
    for k in z:
        np.testing.assert_allclose(np.asarray(out[k]), np.asarray(ref[k]),
                                   rtol=1e-5, atol=1e-6)


def test_trimmed_mean_trim0_is_weighted_mean():
    m = 7
    z = _stacked(m=m)
    rng = np.random.default_rng(2)
    w = rng.random((m, m)).astype(np.float32)
    w[w < 0.3] = 0.0                                  # ragged support
    np.fill_diagonal(w, 1.0)
    out = TrimmedMeanAggregator(0.0).aggregate(z, jnp.asarray(w))
    ref = MeanAggregator().aggregate(z, jnp.asarray(w))
    for k in z:
        np.testing.assert_allclose(np.asarray(out[k]), np.asarray(ref[k]),
                                   rtol=1e-5, atol=1e-6)


def test_krum_selects_a_support_candidate():
    """Krum outputs one of the support rows verbatim — and with a single
    far-away outlier, never the outlier."""
    m = 6
    rng = np.random.default_rng(3)
    vals = rng.normal(size=(m, 4)).astype(np.float32)
    vals[4] = 500.0
    z = {"a": jnp.asarray(vals)}
    w = jnp.full((m, m), 1.0 / m, dtype=jnp.float32)
    out = np.asarray(KrumAggregator(0.25).aggregate(z, w)["a"])
    for i in range(m):
        assert any(np.array_equal(out[i], vals[j]) for j in range(m))
        assert not np.array_equal(out[i], vals[4])


# ---------------------------------------------------------------------------
# Registries
# ---------------------------------------------------------------------------

def test_register_attack_roundtrip():
    class _Noop:
        name = "noop"

        def perturb(self, z, adv, rng):
            return z

    register_attack("noop_test", lambda spec: _Noop(), overwrite=True)
    assert "noop_test" in attack_names()
    spec = ThreatSpec(attack="noop_test", frac=0.5)
    assert make_attack(spec).name == "noop"


def test_register_aggregator_roundtrip():
    register_aggregator("mean_test", lambda cfg: MeanAggregator(),
                        overwrite=True)
    assert "mean_test" in aggregator_names()
    cfg = DFLConfig(m=4, robust="mean_test")
    assert isinstance(make_aggregator(cfg), MeanAggregator)


# ---------------------------------------------------------------------------
# Config validation (satellite: clear errors at construction)
# ---------------------------------------------------------------------------

def test_config_validation_threat_fields():
    with pytest.raises(ValueError, match="threat"):
        DFLConfig(m=4, threat="signflip")             # not a ThreatSpec
    with pytest.raises(ValueError, match="robust"):
        DFLConfig(m=4, robust="majority")
    with pytest.raises(ValueError, match="robust_trim"):
        DFLConfig(m=4, robust_trim=0.5)
    with pytest.raises(ValueError, match="dp_clip"):
        DFLConfig(m=4, dp_clip=0.0)
    with pytest.raises(ValueError, match="dp_noise"):
        DFLConfig(m=4, dp_noise=-0.1)
    with pytest.raises(ValueError, match="codec_bits"):
        DFLConfig(m=4, codec="int8", codec_bits=1)
    with pytest.raises(ValueError, match="codec_k"):
        DFLConfig(m=4, codec="topk", codec_k=0)


# ---------------------------------------------------------------------------
# Bit-identity: empty threat + robust="mean" IS the plain round
# ---------------------------------------------------------------------------

def _bit_identity_case(algo, transport, topology, rounds=3, m=8):
    loss_fn, params, sampler = _toy_problem(m=m)
    base = dict(algorithm=algo, m=m, K=3, topology=topology,
                transport=transport)
    st_p, h_p = simulate(loss_fn, None, params, DFLConfig(**base),
                         sampler, rounds=rounds, seed=0)
    st_t, h_t = simulate(loss_fn, None, params,
                         DFLConfig(**base, threat=ThreatSpec(frac=0.0),
                                   robust="mean"),
                         sampler, rounds=rounds, seed=0)
    assert h_p["loss"] == h_t["loss"]                 # bitwise, every round
    for k in st_p.params:
        np.testing.assert_array_equal(np.asarray(st_p.params[k]),
                                      np.asarray(st_t.params[k]))


@pytest.mark.parametrize("transport,topology", [
    ("dense", "ring"), ("pushsum", "dring")])
def test_zero_adversaries_bit_identical(transport, topology):
    _bit_identity_case("dfedadmm", transport, topology)


@pytest.mark.slow
@pytest.mark.parametrize("algo", sorted(solver_names("dfl")))
def test_zero_adversaries_bit_identical_all_solvers(algo):
    """The acceptance pin: for EVERY registered solver the empty threat
    with robust="mean" produces the bit-identical simulate."""
    for transport, topology in (("dense", "ring"), ("ppermute", "ring"),
                                ("pushsum", "dring")):
        _bit_identity_case(algo, transport, topology, rounds=2)


# ---------------------------------------------------------------------------
# End-to-end: attack + robust mixing inside the jitted round
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("robust", ["trimmed_mean", "median", "krum"])
def test_attacked_round_runs_and_stays_finite(robust):
    loss_fn, params, sampler = _toy_problem(m=8)
    cfg = DFLConfig(algorithm="dfedadmm", m=8, K=3, topology="ring",
                    threat=ThreatSpec(attack="signflip", frac=0.25,
                                      scale=2.0),
                    robust=robust)
    st, h = simulate(loss_fn, None, params, cfg, sampler, rounds=3, seed=0)
    assert np.isfinite(h["loss"]).all()
    assert np.isfinite(np.asarray(st.params["w"])).all()


def test_robust_composes_with_participation_and_async():
    from repro.core import NetworkModel
    m = 8
    loss_fn, params, sampler = _toy_problem(m=m)
    net = NetworkModel(name="flat", bandwidth=np.full((m, m), 1e12),
                       latency=np.zeros((m, m)), jitter=0.0,
                       compute_s=0.002)
    cfg = DFLConfig(algorithm="dfedadmm", m=m, K=3, topology="ring",
                    network=net, execution="async", tick_s=1.0,
                    max_staleness=2,
                    threat=ThreatSpec(attack="zero", frac=0.25),
                    robust="median")
    st, h = simulate(loss_fn, None, params, cfg, sampler, rounds=3, seed=0)
    assert np.isfinite(h["loss"]).all()


def test_robust_rejects_on_mesh_ppermute():
    """The gated-permute path never materializes the neighbourhood, so
    robust mixing on a real mesh is a construction-time error (the
    meshless ppermute fallback stays allowed)."""
    from repro.core import make_gossip, make_transport

    spec = make_gossip("ring", 8)
    cfg = DFLConfig(m=8, transport="ppermute", robust="trimmed_mean")
    make_transport(cfg, spec=spec)                    # meshless: fine
    with pytest.raises(ValueError, match="neighbourhood"):
        make_transport(cfg, spec=spec, mesh=object())


# ---------------------------------------------------------------------------
# DP codec
# ---------------------------------------------------------------------------

def test_dp_codec_clips_to_bound():
    m, d = 4, 64
    rng = np.random.default_rng(0)
    z = {"w": jnp.asarray(10.0 * rng.normal(size=(m, d)), jnp.float32)}
    codec = DPCodec(clip=1.0, noise=0.0)
    wire, resid = codec.encode(z, resid=codec.init_state(z),
                               rng=jax.random.PRNGKey(0))
    out = codec.decode(wire)
    norms = np.linalg.norm(np.asarray(out["w"]), axis=1)
    assert (norms <= 1.0 + 1e-5).all()
    # clip error rides the residual: z = clipped + resid exactly
    np.testing.assert_allclose(np.asarray(out["w"]) + np.asarray(resid["w"]),
                               np.asarray(z["w"]), rtol=1e-5, atol=1e-5)
    assert float(wire["clip_frac"]) == 1.0


def test_dp_codec_noise_not_fed_back():
    """The residual carries ONLY the clipping error — with a message
    already inside the clip bound the residual stays zero no matter the
    noise level (fed-back noise would void the privacy)."""
    m, d = 4, 16
    rng = np.random.default_rng(1)
    z = {"w": jnp.asarray(0.01 * rng.normal(size=(m, d)), jnp.float32)}
    codec = DPCodec(clip=1.0, noise=0.5)
    wire, resid = codec.encode(z, resid=codec.init_state(z),
                               rng=jax.random.PRNGKey(0))
    np.testing.assert_allclose(np.asarray(resid["w"]), 0.0, atol=1e-7)
    assert float(wire["clip_frac"]) == 0.0
    # ... while the wire itself is genuinely randomized
    assert not np.allclose(np.asarray(codec.decode(wire)["w"]),
                           np.asarray(z["w"]), atol=1e-4)


def test_dp_codec_requires_rng():
    z = {"w": jnp.ones((2, 3), jnp.float32)}
    codec = DPCodec(clip=1.0, noise=0.1)
    with pytest.raises(ValueError, match="PRNG"):
        codec.encode(z, resid=codec.init_state(z))


def test_dp_codec_validation():
    with pytest.raises(ValueError, match="dp_clip"):
        DPCodec(clip=-1.0)
    with pytest.raises(ValueError, match="dp_noise"):
        DPCodec(clip=1.0, noise=-0.5)


def test_dp_telemetry_flows_into_history():
    loss_fn, params, sampler = _toy_problem(m=6)
    cfg = DFLConfig(algorithm="dfedadmm", m=6, K=3, topology="ring",
                    codec="dp", dp_clip=0.5, dp_noise=0.05)
    _, h = simulate(loss_fn, None, params, cfg, sampler, rounds=3, seed=0)
    assert len(h["dp_clip_frac"]) == 3
    assert all(0.0 <= v <= 1.0 for v in h["dp_clip_frac"])
    assert h["dp_noise_mult"] == [pytest.approx(0.05)] * 3


def test_dp_telemetry_async_empty_tick_is_nan():
    from repro.core import NetworkModel
    m = 6
    loss_fn, params, sampler = _toy_problem(m=m)
    net = NetworkModel(name="flat", bandwidth=np.full((m, m), 1e12),
                       latency=np.zeros((m, m)), jitter=0.0,
                       compute_s=0.002)
    cfg = DFLConfig(algorithm="dfedavg", m=m, K=3, topology="ring",
                    codec="dp", dp_clip=0.5, dp_noise=0.0, network=net,
                    execution="async", tick_s=0.004, max_staleness=4)
    _, h = simulate(loss_fn, None, params, cfg, sampler, rounds=4, seed=0)
    assert np.isnan(h["dp_clip_frac"][0])             # empty first tick
    assert np.isfinite(h["dp_clip_frac"][1])


# ---------------------------------------------------------------------------
# Acceptance: the headline contrast (slow — full synthetic task)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_signflip20_trimmed_mean_holds_where_mean_fails():
    """20% sign-flip adversaries on the paper's synthetic task: dfedadmm
    with trimmed-mean mixing reaches the target accuracy, plain mean
    does not (pinned by benchmarks/robust_bench.py's headline row)."""
    from benchmarks.common import rounds_from_history, run_dfl
    threat = ThreatSpec(attack="signflip", frac=0.2, scale=1.0, seed=0)
    common = dict(rounds=20, alpha=0.3, m=16, topology="random",
                  eval_every=2, threat=threat)
    acc_m, h_m, _ = run_dfl("dfedadmm", robust="mean", **common)
    acc_t, h_t, _ = run_dfl("dfedadmm", robust="trimmed_mean", **common)
    assert rounds_from_history(h_t, 0.7) is not None
    assert rounds_from_history(h_m, 0.7) is None
    assert acc_t > acc_m + 0.3
