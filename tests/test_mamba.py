"""SSM blocks: chunked scan == naive recurrence; decode == scan."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import mamba


def test_chunked_linear_scan_matches_naive():
    rng = np.random.default_rng(0)
    B, S, D = 2, 24, 5
    a = jnp.asarray(rng.uniform(0.5, 1.0, (B, S, D)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(B, S, D)), jnp.float32)
    h0 = jnp.asarray(rng.normal(size=(B, D)), jnp.float32)
    for chunk in (1, 3, 8, 24, 100):
        h_all, h_last = mamba.chunked_linear_scan(a, b, h0, chunk)
        h = np.asarray(h0)
        ref = []
        for t in range(S):
            h = np.asarray(a[:, t]) * h + np.asarray(b[:, t])
            ref.append(h.copy())
        ref = np.stack(ref, 1)
        np.testing.assert_allclose(np.asarray(h_all), ref, rtol=1e-5,
                                   atol=1e-6)
        np.testing.assert_allclose(np.asarray(h_last), ref[:, -1], rtol=1e-5,
                                   atol=1e-6)


def test_causal_conv_matches_stepwise():
    rng = np.random.default_rng(1)
    B, S, C, Kw = 2, 10, 6, 4
    x = jnp.asarray(rng.normal(size=(B, S, C)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(Kw, C)), jnp.float32)
    bias = jnp.asarray(rng.normal(size=(C,)), jnp.float32)
    full = mamba.causal_conv1d(x, w, bias)
    state = jnp.zeros((B, Kw - 1, C))
    outs = []
    for t in range(S):
        y, state = mamba.conv_step(state, x[:, t:t + 1], w, bias)
        outs.append(np.asarray(y[:, 0]))
    np.testing.assert_allclose(np.stack(outs, 1), np.asarray(full), rtol=1e-5,
                               atol=1e-6)


@pytest.mark.parametrize("variant", ["mamba1", "mamba2"])
def test_decode_recurrence_matches_scan(variant):
    cfg = ModelConfig(name="ssm-test", arch_type="ssm", num_layers=1,
                      d_model=32, ssm_variant=variant, ssm_state=8,
                      ssm_head_dim=16, ssm_chunk=4, vocab_size=64,
                      dtype="float32")
    dtype = jnp.float32
    init = (mamba.init_mamba1_params if variant == "mamba1"
            else mamba.init_mamba2_params)
    params = init(jax.random.PRNGKey(0), cfg, dtype)
    block = mamba.mamba1_block if variant == "mamba1" else mamba.mamba2_block
    step = (mamba.mamba1_decode_step if variant == "mamba1"
            else mamba.mamba2_decode_step)

    B, S = 2, 12
    x = jnp.asarray(np.random.default_rng(2).normal(size=(B, S, 32)) * 0.5,
                    jnp.float32)
    y_full, (h_last, conv_last) = block(params, x, cfg)

    if variant == "mamba1":
        h = jnp.zeros((B, cfg.d_inner, cfg.ssm_state), jnp.float32)
    else:
        h = jnp.zeros((B, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
                      jnp.float32)
    conv = jnp.zeros((B, cfg.d_conv - 1, cfg.d_inner), jnp.float32)
    for t in range(S):
        y_t, h, conv = step(params, x[:, t:t + 1], h, conv, cfg)
        np.testing.assert_allclose(np.asarray(y_t[:, 0]),
                                   np.asarray(y_full[:, t]), rtol=2e-4,
                                   atol=2e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_last), rtol=2e-4,
                               atol=2e-4)


def test_mamba1_kernel_path_matches_jnp():
    """mamba1_block(ssm_kernel=True) == the chunked_ssm jnp path."""
    import dataclasses
    import numpy as np
    from repro.configs.base import ModelConfig
    cfg = ModelConfig(name="m1k", arch_type="ssm", num_layers=1, d_model=32,
                      ssm_variant="mamba1", ssm_state=8, ssm_chunk=16,
                      vocab_size=64, dtype="float32")
    cfg_k = dataclasses.replace(cfg, ssm_kernel=True)
    params = mamba.init_mamba1_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jnp.asarray(np.random.default_rng(1).normal(size=(2, 48, 32)) * 0.3,
                    jnp.float32)
    y_ref, (h_ref, c_ref) = mamba.mamba1_block(params, x, cfg)
    y_k, (h_k, c_k) = mamba.mamba1_block(params, x, cfg_k)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_ref),
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(c_k), np.asarray(c_ref),
                               rtol=1e-6, atol=0)
