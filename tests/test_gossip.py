"""Gossip matrix construction: Definition-1 properties + topology facts."""
import numpy as np
import pytest

from repro.core import gossip


TOPOS = ["ring", "grid", "exp", "full", "random"]


@pytest.mark.parametrize("topo", TOPOS)
@pytest.mark.parametrize("m", [4, 9, 16, 100])
@pytest.mark.parametrize("weights", ["metropolis", "uniform"])
def test_definition1_properties(topo, m, weights):
    spec = gossip.make_gossip(topo, m, weights=weights, seed=3)
    w = spec.matrix
    gossip.validate_gossip_matrix(w)       # symmetric, stochastic, spectrum
    assert np.allclose(w.sum(axis=0), 1.0)  # doubly stochastic
    assert 0.0 <= spec.psi < 1.0           # connected -> spectral gap > 0


def test_spectral_gap_ordering():
    """Paper Sec 5.3: connectivity Ring < Grid < Exp < Full."""
    m = 16
    psis = {t: gossip.make_gossip(t, m).psi for t in ("ring", "grid", "exp",
                                                      "full")}
    assert psis["ring"] > psis["grid"] > psis["exp"] > psis["full"]
    assert psis["full"] < 1e-8  # full graph mixes in one step


def test_ring_degree():
    adj = gossip.ring_adjacency(10)
    assert (adj.sum(axis=1) == 2).all()


def test_exp_neighbor_count():
    adj = gossip.exp_adjacency(16)
    # i +/- {1,2,4,8}: 8 mod 16 gives same node both directions -> 7 distinct
    assert (adj.sum(axis=1) == 7).all()


def test_random_time_varying_differs():
    specs = gossip.time_varying_specs("random", 20, 5, degree=6, base_seed=0)
    mats = [s.matrix for s in specs]
    assert not np.allclose(mats[0], mats[1])
    for s in specs:
        gossip.validate_gossip_matrix(s.matrix)


def test_circulant_detection():
    assert gossip.make_gossip("ring", 8).is_circulant()
    assert gossip.make_gossip("full", 8).is_circulant()
    assert gossip.make_gossip("exp", 8).is_circulant()


def test_neighbor_offsets_ring():
    spec = gossip.make_gossip("ring", 8)
    assert spec.neighbor_offsets() == [1, 7]


def test_grid_is_torus():
    adj = gossip.grid_adjacency(16)
    assert (adj.sum(axis=1) == 4).all()


def test_unknown_topology_raises():
    with pytest.raises(ValueError):
        gossip.adjacency("hypercube", 8)
