"""Gossip matrix construction: Definition-1 properties + topology facts."""
import numpy as np
import pytest

from repro.core import gossip


TOPOS = ["ring", "grid", "exp", "full", "random"]


@pytest.mark.parametrize("topo", TOPOS)
@pytest.mark.parametrize("m", [4, 9, 16, 100])
@pytest.mark.parametrize("weights", ["metropolis", "uniform"])
def test_definition1_properties(topo, m, weights):
    spec = gossip.make_gossip(topo, m, weights=weights, seed=3)
    w = spec.matrix
    gossip.validate_gossip_matrix(w)       # symmetric, stochastic, spectrum
    assert np.allclose(w.sum(axis=0), 1.0)  # doubly stochastic
    assert 0.0 <= spec.psi < 1.0           # connected -> spectral gap > 0


def test_spectral_gap_ordering():
    """Paper Sec 5.3: connectivity Ring < Grid < Exp < Full."""
    m = 16
    psis = {t: gossip.make_gossip(t, m).psi for t in ("ring", "grid", "exp",
                                                      "full")}
    assert psis["ring"] > psis["grid"] > psis["exp"] > psis["full"]
    assert psis["full"] < 1e-8  # full graph mixes in one step


def test_ring_degree():
    adj = gossip.ring_adjacency(10)
    assert (adj.sum(axis=1) == 2).all()


def test_exp_neighbor_count():
    adj = gossip.exp_adjacency(16)
    # i +/- {1,2,4,8}: 8 mod 16 gives same node both directions -> 7 distinct
    assert (adj.sum(axis=1) == 7).all()


def test_random_time_varying_differs():
    specs = gossip.time_varying_specs("random", 20, 5, degree=6, base_seed=0)
    mats = [s.matrix for s in specs]
    assert not np.allclose(mats[0], mats[1])
    for s in specs:
        gossip.validate_gossip_matrix(s.matrix)


def test_circulant_detection():
    assert gossip.make_gossip("ring", 8).is_circulant()
    assert gossip.make_gossip("full", 8).is_circulant()
    assert gossip.make_gossip("exp", 8).is_circulant()


def test_neighbor_offsets_ring():
    spec = gossip.make_gossip("ring", 8)
    assert spec.neighbor_offsets() == [1, 7]


def test_grid_is_torus():
    adj = gossip.grid_adjacency(16)
    assert (adj.sum(axis=1) == 4).all()


def test_unknown_topology_raises():
    with pytest.raises(ValueError):
        gossip.adjacency("hypercube", 8)


# ---------------------------------------------------------------------------
# Edge cases
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("degree", [5, 6, 100])
def test_random_adjacency_degree_clamped_to_full(degree):
    """degree >= m-1 clamps to the complete graph instead of erroring."""
    m = 6
    adj = gossip.random_adjacency(m, degree, seed=0)
    assert (adj.sum(axis=1) == m - 1).all()
    assert not adj.diagonal().any()
    gossip.validate_gossip_matrix(gossip.metropolis_weights(adj))


@pytest.mark.parametrize("m", [5, 7, 13])
def test_grid_prime_m_falls_back_to_ring(m):
    """Prime m has no r*c factorization with r >= 2 -> degenerate 1-row
    grid, which must collapse to the ring."""
    np.testing.assert_array_equal(gossip.grid_adjacency(m),
                                  gossip.ring_adjacency(m))
    gossip.validate_gossip_matrix(gossip.make_gossip("grid", m).matrix)


def test_neighbor_offsets_non_circulant_is_offset_union():
    """On a non-circulant matrix neighbor_offsets degrades to the union of
    per-client offsets: still well-formed (sorted, in [1, m-1]) but NOT a
    valid per-client pattern — the ppermute path must refuse it."""
    from repro.core import mixing
    m = 9
    spec = gossip.make_gossip("random", m, degree=3, seed=2)
    assert not spec.is_circulant()
    offs = spec.neighbor_offsets()
    assert offs == sorted(set(offs))
    assert all(1 <= o <= m - 1 for o in offs)
    # the union over-counts any single client's neighbourhood
    row_deg = (spec.matrix[0] > 0).sum() - 1
    assert len(offs) > row_deg
    with pytest.raises(ValueError):
        mixing._circulant_pattern(spec)


def test_grid_torus_not_circulant_under_row_major_ids():
    spec = gossip.make_gossip("grid", 12)
    assert not spec.is_circulant()
    with pytest.raises(ValueError):
        from repro.core import mixing
        mixing._circulant_pattern(spec)


def test_two_client_edge_case():
    for topo in ("ring", "exp", "full"):
        spec = gossip.make_gossip(topo, 2)
        gossip.validate_gossip_matrix(spec.matrix)
    with pytest.raises(ValueError):
        gossip.ring_adjacency(1)
