"""Partial-participation scenario engine: masked gossip algebra, spec
sampling, and end-to-end behaviour of the masked round loop."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (DFLConfig, ParticipationSpec, make_gossip,
                        make_train_round, mask_and_renormalize, simulate,
                        spectral_psi, time_varying_specs,
                        validate_gossip_matrix)
from repro.core.dfl import init_state
from repro.core.participation import (participation_schedule,
                                      round_participation, sample_mask,
                                      straggler_set)
from repro.data.synthetic import SyntheticClassification


# ---------------------------------------------------------------------------
# mask_and_renormalize
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("topo", ["ring", "grid", "exp", "full", "random"])
def test_masked_matrix_keeps_definition1(topo):
    m = 12
    w = make_gossip(topo, m, degree=4, seed=1).matrix
    active = np.ones(m, dtype=bool)
    active[[1, 5, 6, 10]] = False
    wm = mask_and_renormalize(w, active)
    validate_gossip_matrix(wm)                       # symmetric + stochastic
    assert np.allclose(wm, wm.T)
    assert np.allclose(wm.sum(axis=1), 1.0)
    assert np.allclose(wm.sum(axis=0), 1.0)          # doubly stochastic
    assert ((wm >= 0) & (wm <= 1)).all()


def test_masked_inactive_rows_are_identity():
    m = 8
    w = make_gossip("full", m).matrix
    active = np.array([True, False, True, True, False, True, True, True])
    wm = mask_and_renormalize(w, active)
    for i in np.flatnonzero(~active):
        expected = np.zeros(m)
        expected[i] = 1.0
        np.testing.assert_array_equal(wm[i], expected)
        np.testing.assert_array_equal(wm[:, i], expected)


def test_masked_all_active_is_identity_operation():
    w = make_gossip("exp", 16).matrix
    wm = mask_and_renormalize(w, np.ones(16, dtype=bool))
    np.testing.assert_allclose(wm, w, atol=1e-12)


def test_masked_off_diagonals_preserved_among_active():
    m = 10
    w = make_gossip("random", m, degree=5, seed=7).matrix
    active = np.ones(m, dtype=bool)
    active[[0, 3]] = False
    wm = mask_and_renormalize(w, active)
    act = np.flatnonzero(active)
    for i in act:
        for j in act:
            if i != j:
                assert wm[i, j] == w[i, j]


def test_masked_spectral_gap_sanity():
    m = 12
    w = make_gossip("full", m).matrix
    active = np.ones(m, dtype=bool)
    active[:4] = False
    wm = mask_and_renormalize(w, active)
    # the full m-node matrix has eigenvalue 1 with multiplicity 1 + #inactive
    # -> psi == 1: inactive clients genuinely do not mix this round
    assert spectral_psi(wm) == pytest.approx(1.0, abs=1e-9)
    # but the active subgraph itself still mixes: its principal submatrix
    # is a valid gossip matrix with a positive spectral gap
    sub = wm[np.ix_(active, active)]
    validate_gossip_matrix(sub)
    assert spectral_psi(sub) < 1.0 - 1e-6


def test_masked_shape_mismatch_raises():
    w = make_gossip("ring", 8).matrix
    with pytest.raises(ValueError):
        mask_and_renormalize(w, np.ones(6, dtype=bool))


def test_time_varying_specs_compose_with_masks():
    m, rounds = 10, 6
    spec = ParticipationSpec(mode="fraction", p=0.6)
    masks = [rp.active for rp in participation_schedule(spec, m, rounds, K=5)]
    specs = time_varying_specs("random", m, rounds, degree=4, masks=masks)
    assert len(specs) == rounds
    for s, a in zip(specs, masks):
        validate_gossip_matrix(s.matrix)
        for i in np.flatnonzero(~a):
            assert s.matrix[i, i] == 1.0
    with pytest.raises(ValueError):
        time_varying_specs("ring", m, rounds, masks=masks[:-1])


def test_fifty_round_random_topology_masked_all_valid():
    m, rounds = 16, 50
    spec = ParticipationSpec(mode="uniform", p=0.5, dropout=0.1, seed=3)
    sched = participation_schedule(spec, m, rounds, K=5)
    base = time_varying_specs("random", m, rounds, degree=6, base_seed=11)
    for s, rp in zip(base, sched):
        validate_gossip_matrix(mask_and_renormalize(s.matrix, rp.active))


# ---------------------------------------------------------------------------
# ParticipationSpec sampling
# ---------------------------------------------------------------------------

def test_fraction_mode_exact_count():
    spec = ParticipationSpec(mode="fraction", p=0.5)
    for t in range(10):
        assert sample_mask(spec, 16, t).sum() == 8


def test_uniform_mode_respects_min_active():
    spec = ParticipationSpec(mode="uniform", p=0.01, min_active=3, seed=0)
    for t in range(20):
        assert sample_mask(spec, 12, t).sum() >= 3


def test_min_active_zero_allows_empty_rounds():
    """min_active=0 disables the floor: a low-p sweep keeps its true
    rate instead of being silently inflated."""
    spec = ParticipationSpec(mode="uniform", p=0.05, min_active=0, seed=0)
    counts = [sample_mask(spec, 16, t).sum() for t in range(100)]
    assert min(counts) == 0                      # empty rounds do occur
    assert np.mean(counts) < 3                   # rate stays near 0.05*16
    with pytest.raises(ValueError):
        ParticipationSpec(min_active=-1)


def test_schedule_mode_cycles_and_validates():
    spec = ParticipationSpec(mode="schedule", schedule=((0, 1), (2, 3, 4)))
    m0 = sample_mask(spec, 6, 0)
    assert np.flatnonzero(m0).tolist() == [0, 1]
    assert np.flatnonzero(sample_mask(spec, 6, 1)).tolist() == [2, 3, 4]
    np.testing.assert_array_equal(sample_mask(spec, 6, 2), m0)  # cycles
    bad = ParticipationSpec(mode="schedule", schedule=((0, 99),))
    with pytest.raises(ValueError):
        sample_mask(bad, 6, 0)


def test_straggler_set_is_fixed_and_sized():
    spec = ParticipationSpec(straggler_frac=0.25, straggler_steps=2)
    s0 = straggler_set(spec, 16)
    assert s0.sum() == 4
    np.testing.assert_array_equal(s0, straggler_set(spec, 16))


def test_round_participation_steps_vector():
    spec = ParticipationSpec(mode="fraction", p=0.5, straggler_frac=0.25,
                             straggler_steps=2, seed=1)
    rp = round_participation(spec, 16, 0, K=5)
    stragglers = straggler_set(spec, 16)
    assert (rp.steps[~rp.active] == 0).all()
    assert (rp.steps[rp.active & stragglers] == 2).all()
    assert (rp.steps[rp.active & ~stragglers] == 5).all()
    assert rp.sampled.sum() >= rp.active.sum()


def test_dropout_never_empties_a_sampled_round():
    """Even with extreme dropout, a round that sampled anyone keeps at
    least one survivor so the loss metric stays measurable."""
    spec = ParticipationSpec(mode="uniform", p=0.3, dropout=0.9, seed=0)
    for t in range(50):
        rp = round_participation(spec, 8, t, K=5)
        assert rp.sampled.any()
        assert rp.active.any()


def test_empty_schedule_round_reports_nan_loss():
    """A schedule entry with no clients has no loss measurement: the
    metric must be NaN, not a spurious 0.0."""
    m, K = 4, 2
    part = ParticipationSpec(mode="schedule", schedule=((0, 1), ()))
    rp = round_participation(part, m, 1, K=K)
    assert not rp.active.any()
    cfg = DFLConfig(algorithm="dfedadmm", m=m, K=K, topology="full",
                    lam=0.2, participation=part)
    spec = make_gossip("full", m)
    params = {"w": jnp.ones((3,), jnp.float32)}
    state = init_state(params, cfg, seed=0)
    rng = np.random.default_rng(0)
    batches = {"x": jnp.asarray(rng.normal(size=(m, K, 4, 3)), jnp.float32),
               "y": jnp.asarray(rng.normal(size=(m, K, 4)), jnp.float32)}

    def loss_fn(p, batch, r):
        return jnp.mean((batch["x"] @ p["w"] - batch["y"]) ** 2)

    round_fn = jax.jit(make_train_round(loss_fn, cfg, spec=spec,
                                        metrics="light"))
    w = jnp.asarray(mask_and_renormalize(spec.matrix, rp.active), jnp.float32)
    new_state, metrics = round_fn(state, batches, w, jnp.asarray(rp.active),
                                  jnp.asarray(rp.steps))
    assert np.isnan(float(metrics["loss"]))
    assert float(metrics["participation"]) == 0.0
    np.testing.assert_array_equal(np.asarray(new_state.params["w"]),
                                  np.asarray(state.params["w"]))


def test_dropout_subset_and_wasted_accounting():
    spec = ParticipationSpec(mode="uniform", p=0.9, dropout=0.5, seed=2)
    rp = round_participation(spec, 32, 0, K=5)
    assert (rp.sampled | ~rp.active).all()        # active subset of sampled
    assert rp.wasted == int(rp.sampled.sum() - rp.active.sum())


def test_schedule_is_deterministic():
    spec = ParticipationSpec(mode="uniform", p=0.5, dropout=0.2,
                             straggler_frac=0.5, seed=9)
    a = participation_schedule(spec, 10, 7, K=5)
    b = participation_schedule(spec, 10, 7, K=5)
    for ra, rb in zip(a, b):
        np.testing.assert_array_equal(ra.active, rb.active)
        np.testing.assert_array_equal(ra.steps, rb.steps)


def test_trivial_detection_and_validation():
    assert ParticipationSpec().is_trivial
    assert not ParticipationSpec(mode="uniform", p=0.5).is_trivial
    assert not ParticipationSpec(dropout=0.1).is_trivial
    assert not ParticipationSpec(straggler_frac=0.5).is_trivial
    for bad in (dict(mode="lottery"), dict(p=0.0), dict(p=1.5),
                dict(dropout=1.0), dict(straggler_frac=2.0),
                dict(straggler_steps=0), dict(mode="schedule")):
        with pytest.raises(ValueError):
            ParticipationSpec(**bad)


def test_ppermute_participation_now_supported():
    """The ppermute transport accepts partial participation since the
    comm-layer redesign: `Transport.prepare` gates the permute sends
    instead of materializing the non-circulant masked matrix."""
    cfg = DFLConfig(transport="ppermute", topology="ring",
                    participation=ParticipationSpec(mode="uniform", p=0.5))
    assert cfg.transport == "ppermute"


def test_ppermute_gates_realize_masked_matrix():
    """ppermute_gates(spec, active) @ z == mask_and_renormalize(W) @ z:
    the gated circulant exchange is the masked matrix, offset by offset."""
    from repro.core import mixing
    m = 8
    spec = make_gossip("exp", m)
    active = np.array([True, False, True, True, False, True, True, True])
    gates, self_w = mixing.ppermute_gates(spec, active)
    wm = mask_and_renormalize(spec.matrix, active)
    # reassemble the dense matrix from the gated circulant pattern
    pattern = [(off, wgt) for off, wgt in mixing._circulant_pattern(spec)
               if off != 0]
    rebuilt = np.diag(self_w.astype(np.float64))
    for col, (off, wgt) in enumerate(pattern):
        for i in range(m):
            rebuilt[i, (i - off) % m] += wgt * gates[i, col]
    np.testing.assert_allclose(rebuilt, wm, atol=1e-6)
    # inactive clients: gate row zero, self weight exactly 1
    for i in np.flatnonzero(~active):
        assert self_w[i] == 1.0
        np.testing.assert_array_equal(gates[i], 0.0)


# ---------------------------------------------------------------------------
# End-to-end: masked round loop
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=1)
def _task():
    return SyntheticClassification(n_classes=6, dim=12, n_train=1500,
                                   n_test=300, noise=1.0, seed=0)


def _mlp_init(dim, n_classes, hidden=16, seed=0):
    rng = np.random.default_rng(seed)
    return {
        "w1": jnp.asarray(rng.normal(size=(dim, hidden)) / np.sqrt(dim),
                          jnp.float32),
        "b1": jnp.zeros(hidden),
        "w2": jnp.asarray(rng.normal(size=(hidden, n_classes)) /
                          np.sqrt(hidden), jnp.float32),
        "b2": jnp.zeros(n_classes),
    }


def _loss(params, batch, rng):
    h = jnp.tanh(batch["x"] @ params["w1"] + params["b1"])
    logits = h @ params["w2"] + params["b2"]
    logz = jax.nn.logsumexp(logits, -1)
    gold = jnp.take_along_axis(logits, batch["y"][..., None], -1)[..., 0]
    return jnp.mean(logz - gold)


def _simulate(participation, rounds, algo="dfedadmm", m=8, K=3, seed=0):
    task = _task()
    parts = task.partition(m, 0.3, seed=seed)
    sampler0 = task.client_sampler(parts, batch=16, K=K, seed=seed)

    def sampler(t):
        b = sampler0(t)
        return {"x": jnp.asarray(b["x"]), "y": jnp.asarray(b["y"])}

    cfg = DFLConfig(algorithm=algo, m=m, K=K, topology="random", degree=4,
                    lam=0.2, participation=participation)
    params = _mlp_init(task.dim, task.n_classes)
    return simulate(_loss, None, params, cfg, sampler, rounds=rounds,
                    seed=seed)


def test_full_participation_bit_identical_to_seed_path():
    """participation 1.0 through the masked machinery == the untouched
    paper code path, bit for bit (losses and parameters)."""
    state_a, hist_a = _simulate(ParticipationSpec(), rounds=6)
    state_b, hist_b = _simulate(ParticipationSpec(mode="fraction", p=1.0),
                                rounds=6)
    np.testing.assert_array_equal(np.asarray(hist_a["loss"]),
                                  np.asarray(hist_b["loss"]))
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), state_a.params, state_b.params)
    assert hist_b["participation"] == [1.0] * 6


@pytest.mark.slow
def test_half_participation_still_converges():
    """0.5 participation reaches a loss within 2x of full participation
    in at most 2x the rounds (acceptance criterion)."""
    _, hist_full = _simulate(ParticipationSpec(), rounds=10)
    _, hist_half = _simulate(ParticipationSpec(mode="fraction", p=0.5),
                             rounds=20)
    assert hist_half["loss"][-1] < hist_half["loss"][0]        # it learns
    assert hist_half["loss"][-1] <= 2.0 * hist_full["loss"][-1]
    assert hist_half["participation"] == [0.5] * 20


def test_inactive_clients_hold_state_one_round():
    """Direct round_fn check: inactive clients' params, dual, and momentum
    are bitwise frozen across a masked round."""
    m, K = 6, 3
    cfg = DFLConfig(algorithm="dfedadmm", m=m, K=K, topology="full",
                    lam=0.2,
                    participation=ParticipationSpec(mode="fraction", p=0.5))
    spec = make_gossip("full", m)
    params = {"w": jnp.ones((4, 3), jnp.float32)}
    state = init_state(params, cfg, seed=0)
    rng = np.random.default_rng(0)
    batches = {"x": jnp.asarray(rng.normal(size=(m, K, 8, 4)), jnp.float32),
               "y": jnp.asarray(rng.normal(size=(m, K, 8, 3)), jnp.float32)}

    def loss_fn(p, batch, r):
        return jnp.mean((batch["x"] @ p["w"] - batch["y"]) ** 2)

    active = np.array([True, False, True, False, True, True])
    steps = np.where(active, K, 0).astype(np.int32)
    w = mask_and_renormalize(spec.matrix, active)
    round_fn = jax.jit(make_train_round(loss_fn, cfg, spec=spec))
    new_state, metrics = round_fn(state, batches,
                                  jnp.asarray(w, jnp.float32),
                                  jnp.asarray(active), jnp.asarray(steps))
    for i in np.flatnonzero(~active):
        np.testing.assert_array_equal(np.asarray(new_state.params["w"][i]),
                                      np.asarray(state.params["w"][i]))
        np.testing.assert_array_equal(
            np.asarray(new_state.solver["dual"]["w"][i]),
            np.asarray(state.solver["dual"]["w"][i]))
    for i in np.flatnonzero(active):   # active clients did move
        assert not np.array_equal(np.asarray(new_state.params["w"][i]),
                                  np.asarray(state.params["w"][i]))
    assert float(metrics["participation"]) == pytest.approx(4 / 6)


def test_straggler_does_fewer_steps_than_full_client():
    """A straggler's one-round displacement is driven by fewer local
    steps: freezing after step 1 must differ from the full-K client run
    with identical data."""
    m, K = 4, 4
    part = ParticipationSpec(straggler_frac=0.5, straggler_steps=1, seed=0)
    cfg = DFLConfig(algorithm="dfedavg", m=m, K=K, topology="full",
                    participation=part)
    spec = make_gossip("full", m)
    params = {"w": jnp.ones((3,), jnp.float32)}
    state = init_state(params, cfg, seed=0)
    rng = np.random.default_rng(0)
    batches = {"x": jnp.asarray(rng.normal(size=(m, K, 8, 3)), jnp.float32),
               "y": jnp.asarray(rng.normal(size=(m, K, 8)), jnp.float32)}

    def loss_fn(p, batch, r):
        return jnp.mean((batch["x"] @ p["w"] - batch["y"]) ** 2)

    round_fn = jax.jit(make_train_round(loss_fn, cfg, spec=spec,
                                        metrics="light"))
    active = np.ones(m, dtype=bool)
    w = jnp.asarray(spec.matrix, jnp.float32)

    outs = {}
    for name, steps in (("straggle", np.array([1, 1, K, K], np.int32)),
                        ("full", np.full(m, K, np.int32))):
        st, _ = round_fn(state, batches, w, jnp.asarray(active),
                         jnp.asarray(steps))
        outs[name] = np.asarray(st.params["w"])
    assert not np.allclose(outs["straggle"], outs["full"])


@pytest.mark.slow
def test_dropout_and_straggler_scenario_end_to_end():
    part = ParticipationSpec(mode="uniform", p=0.8, dropout=0.2,
                             straggler_frac=0.25, straggler_steps=1, seed=4)
    _, hist = _simulate(part, rounds=10, algo="dfedavgm")
    assert np.isfinite(hist["loss"]).all()
    assert hist["loss"][-1] < hist["loss"][0]
    assert all(0.0 <= p <= 1.0 for p in hist["participation"])
