"""Markdown link checker for the docs tree (no dependencies, no network).

Walks the repo's documentation surfaces (README.md, ROADMAP.md,
EXPERIMENTS.md, docs/*.md) and verifies that every relative link target
exists on disk (anchors stripped), resolved relative to the file that
makes the link.

External (http/https/mailto) links are not fetched — CI must stay
hermetic.  Exit status 1 on any broken link, listing all of them.

Usage: ``python tools/check_links.py [root]``
"""
from __future__ import annotations

import os
import re
import sys

# [text](target) — excluding images' leading ! is harmless to include
_LINK = re.compile(r"\[[^\]\[]*\]\(([^)\s]+)\)")

SURFACES = ("README.md", "ROADMAP.md", "EXPERIMENTS.md", "CHANGES.md")


def doc_files(root: str) -> list[str]:
    files = [os.path.join(root, f) for f in SURFACES
             if os.path.exists(os.path.join(root, f))]
    docs = os.path.join(root, "docs")
    if os.path.isdir(docs):
        files.extend(os.path.join(docs, f) for f in sorted(os.listdir(docs))
                     if f.endswith(".md"))
    return files


def check_file(path: str, root: str) -> list[str]:
    errors = []
    text = open(path).read()
    for match in _LINK.finditer(text):
        target = match.group(1)
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        if target.startswith("#"):              # intra-page anchor
            continue
        rel = target.split("#", 1)[0]
        resolved = os.path.normpath(os.path.join(os.path.dirname(path), rel))
        if not os.path.exists(resolved):
            errors.append(f"{os.path.relpath(path, root)}: broken link "
                          f"{target!r} -> {os.path.relpath(resolved, root)}")
    return errors


def main(argv=None) -> int:
    root = (argv or sys.argv[1:] or ["."])[0]
    files = doc_files(root)
    if not files:
        print("check_links: no markdown surfaces found", file=sys.stderr)
        return 1
    errors = []
    for f in files:
        errors.extend(check_file(f, root))
    for e in errors:
        print(e, file=sys.stderr)
    print(f"check_links: {len(files)} files, "
          f"{len(errors)} broken link(s)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
