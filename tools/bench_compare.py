"""Gate CI on benchmark regressions: diff a ``BENCH_<suite>.json`` run
against a committed baseline.

Usage::

    python tools/bench_compare.py BASELINE.json CURRENT.json \
        [--threshold 2.5] [--metric-threshold NAME=RATIO ...] \
        [--spread-mult 4.0] [--allow-missing]

A metric regresses when its ``us_per_call`` exceeds BOTH guards:

* ``baseline * threshold`` — the relative bar (``--metric-threshold``
  overrides it per row name, e.g. for a known-noisy measurement);
* ``baseline + spread_mult * spread_us`` — the noise bar: a timing that
  moved by less than a few interquartile ranges of the baseline's own
  repeat spread is jitter, not a regression (the spread comes from
  ``benchmarks.common.time_stats``; rows without one fall back to the
  relative bar alone).

Rows present in the baseline but missing from the run fail loudly (a
renamed benchmark silently un-gates itself otherwise) unless
``--allow-missing``; rows new in the run are reported but pass — commit
a refreshed baseline to start gating them (see docs/benchmarks.md,
"Refreshing a baseline").

Exit code 0 = no regressions, 1 = regressions (or missing metrics),
2 = bad invocation/schema.
"""
from __future__ import annotations

import argparse
import json
import sys


def _load(path: str) -> dict:
    with open(path) as f:
        doc = json.load(f)
    if not isinstance(doc, dict) or "rows" not in doc:
        raise ValueError(f"{path}: not a BENCH_*.json document "
                         "(missing 'rows')")
    for row in doc["rows"]:
        if "name" not in row or "us_per_call" not in row:
            raise ValueError(f"{path}: row without name/us_per_call: {row}")
    return doc


def compare(baseline: dict, current: dict, threshold: float = 2.5,
            metric_thresholds: dict | None = None,
            spread_mult: float = 4.0, allow_missing: bool = False) -> dict:
    """Diff two BENCH documents; returns ``{"regressions", "missing",
    "new", "ok"}`` — lists of per-row result dicts.  A row fails only if
    it clears both the relative threshold and the baseline-spread noise
    guard (see module docstring)."""
    metric_thresholds = metric_thresholds or {}
    base = {r["name"]: r for r in baseline["rows"]}
    cur = {r["name"]: r for r in current["rows"]}
    out: dict = {"regressions": [], "missing": [], "new": [], "ok": []}
    for name, b in base.items():
        if name not in cur:
            out["missing"].append({"name": name})
            continue
        b_us = float(b["us_per_call"])
        c_us = float(cur[name]["us_per_call"])
        thr = float(metric_thresholds.get(name, threshold))
        rel_bar = b_us * thr
        spread = b.get("spread_us")
        noise_bar = b_us + spread_mult * float(spread) \
            if spread is not None else None
        allowed = rel_bar if noise_bar is None else max(rel_bar, noise_bar)
        row = {"name": name, "baseline_us": b_us, "current_us": c_us,
               "ratio": c_us / b_us if b_us else float("inf"),
               "allowed_us": allowed}
        out["regressions" if c_us > allowed else "ok"].append(row)
    for name in cur:
        if name not in base:
            out["new"].append({"name": name})
    out["failed"] = bool(out["regressions"]) or \
        (bool(out["missing"]) and not allow_missing)
    return out


def _parse_metric_thresholds(pairs: list[str]) -> dict:
    thr = {}
    for p in pairs:
        name, _, v = p.rpartition("=")
        if not name:
            raise ValueError(f"--metric-threshold wants NAME=RATIO, got {p!r}")
        thr[name] = float(v)
    return thr


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Fail when a BENCH_*.json run regresses vs a baseline")
    ap.add_argument("baseline")
    ap.add_argument("current")
    ap.add_argument("--threshold", type=float, default=2.5,
                    help="fail when current > baseline * THRESHOLD "
                         "(default 2.5 — CI runners are not the machine "
                         "the baseline was recorded on)")
    ap.add_argument("--metric-threshold", action="append", default=[],
                    metavar="NAME=RATIO", help="per-row threshold override")
    ap.add_argument("--spread-mult", type=float, default=4.0,
                    help="noise guard: also require current > baseline + "
                         "SPREAD_MULT * baseline spread_us (default 4.0)")
    ap.add_argument("--allow-missing", action="store_true",
                    help="baseline rows absent from the run warn instead "
                         "of failing")
    args = ap.parse_args(argv)
    try:
        baseline = _load(args.baseline)
        current = _load(args.current)
        metric_thr = _parse_metric_thresholds(args.metric_threshold)
    except (OSError, ValueError, json.JSONDecodeError) as e:
        print(f"bench_compare: {e}", file=sys.stderr)
        return 2
    res = compare(baseline, current, threshold=args.threshold,
                  metric_thresholds=metric_thr,
                  spread_mult=args.spread_mult,
                  allow_missing=args.allow_missing)

    for row in res["ok"]:
        print(f"ok         {row['name']}: {row['current_us']:.1f}us "
              f"({row['ratio']:.2f}x of baseline)")
    for row in res["new"]:
        print(f"new        {row['name']}: not in baseline (passes; refresh "
              "the baseline to gate it)")
    for row in res["missing"]:
        print(f"missing    {row['name']}: in baseline but absent from run"
              + (" (allowed)" if args.allow_missing else ""))
    for row in res["regressions"]:
        print(f"REGRESSION {row['name']}: {row['current_us']:.1f}us vs "
              f"baseline {row['baseline_us']:.1f}us "
              f"({row['ratio']:.2f}x; allowed {row['allowed_us']:.1f}us)")
    n_reg, n_miss = len(res["regressions"]), len(res["missing"])
    print(f"bench_compare: {len(res['ok'])} ok, {len(res['new'])} new, "
          f"{n_miss} missing, {n_reg} regressed "
          f"({baseline.get('suite', '?')} suite)")
    return 1 if res["failed"] else 0


if __name__ == "__main__":
    sys.exit(main())
