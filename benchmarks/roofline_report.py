"""Roofline report: aggregates the dry-run artifacts
(experiments/artifacts/*.json) into the per-(arch x shape x mesh) table of
EXPERIMENTS.md §Roofline."""
from __future__ import annotations

from benchmarks.common import emit


def _fmt_t(rec):
    r = rec["roofline"]
    return (f"dom={r['dominant']};t_c={r['t_compute_s']:.3e}s;"
            f"t_m={r['t_memory_s']:.3e}s;t_x={r['t_collective_s']:.3e}s;"
            f"useful={r['useful_flops_ratio']:.3f}")


def run(variant: str | None = None):
    from repro.launch.dryrun_lib import load_records
    records = load_records()
    if not records:
        emit("roofline/no-artifacts", 0.0,
             "run `python -m repro.launch.dryrun --all --both-meshes` first")
        return []
    rows = []
    for rec in records:
        if variant and rec.get("variant") != variant:
            continue
        name = (f"roofline/{rec['arch']}/{rec['shape']}/{rec['mesh']}/"
                f"{rec.get('variant', 'baseline')}")
        if rec["status"] != "ok":
            emit(name, 0.0, f"skipped:{rec['reason'][:60]}")
            continue
        dom_t = max(rec["roofline"]["t_compute_s"],
                    rec["roofline"]["t_memory_s"],
                    rec["roofline"]["t_collective_s"])
        emit(name, dom_t * 1e6, _fmt_t(rec))
        rows.append(rec)
    return rows


def markdown_table(records=None) -> str:
    """Render the §Roofline markdown table from artifacts."""
    from repro.launch.dryrun_lib import load_records
    records = records or load_records()
    lines = [
        "| arch | shape | mesh | variant | t_comp (s) | t_mem (s) | "
        "t_coll (s) | dominant | useful FLOPs | args/dev (GB) | fits 16GB |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for rec in records:
        if rec["status"] != "ok":
            lines.append(
                f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} | "
                f"{rec.get('variant','-')} | - | - | - | SKIP | - | - | - |")
            continue
        r = rec["roofline"]
        m = rec["memory"]
        arg_gb = (m.get("argument_bytes") or 0) / 1e9
        tmp_gb = (m.get("temp_bytes") or 0) / 1e9
        fits = "yes" if (arg_gb + tmp_gb) < 16.0 else f"NO ({arg_gb+tmp_gb:.0f}GB)"
        lines.append(
            f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} | "
            f"{rec.get('variant','-')} | {r['t_compute_s']:.3e} | "
            f"{r['t_memory_s']:.3e} | {r['t_collective_s']:.3e} | "
            f"**{r['dominant']}** | {r['useful_flops_ratio']:.3f} | "
            f"{arg_gb:.2f} | {fits} |")
    return "\n".join(lines)
