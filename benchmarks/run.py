"""Benchmark suite entry point — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (see benchmarks/common.py).
``--quick`` shrinks round counts for CI; default sizes reproduce the
paper's qualitative orderings.

``--dump-json DIR`` additionally persists each executed suite's rows as
``DIR/BENCH_<suite>.json`` (schema documented in docs/benchmarks.md):
the artifact the CI perf job uploads and feeds to
``tools/bench_compare.py`` against the committed baselines in
``benchmarks/baselines/``.  All non-timing fields are deterministic for
a fixed seed — only ``us_per_call``/``spread_us`` vary between runs.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

BENCH_SCHEMA_VERSION = 1

SUITES = ("table1", "table2", "table345", "fig3", "kernels", "arch_step",
          "roofline", "participation", "comm", "net", "async", "robust",
          "scale")


def _run_suite(suite: str, quick: bool) -> None:
    if suite == "table1":
        from benchmarks import table1_accuracy
        table1_accuracy.run(rounds=15 if quick else 40)
    elif suite == "table2":
        from benchmarks import table2_topology
        table2_topology.run(rounds=12 if quick else 30)
    elif suite == "table345":
        from benchmarks import table345_convergence
        table345_convergence.run(max_rounds=16 if quick else 40,
                                 target=0.6 if quick else 0.7)
    elif suite == "fig3":
        from benchmarks import fig3_ablations
        fig3_ablations.run(rounds=10 if quick else 25)
    elif suite == "kernels":
        from benchmarks import kernels_bench
        kernels_bench.run(quick=quick)
    elif suite == "arch_step":
        from benchmarks import arch_step_bench
        archs = ("llama3-8b", "mixtral-8x7b", "falcon-mamba-7b",
                 "zamba2-1.2b") if quick else None
        arch_step_bench.run(archs)
    elif suite == "roofline":
        from benchmarks import roofline_report
        roofline_report.run()
    elif suite == "participation":
        from benchmarks import participation_bench
        participation_bench.run(rounds=10 if quick else 20)
    elif suite == "comm":
        from benchmarks import comm_bench
        comm_bench.run(rounds=10 if quick else 20,
                       target=0.5 if quick else 0.6)
    elif suite == "net":
        from benchmarks import net_bench
        net_bench.run(rounds=10 if quick else 20,
                      target=0.5 if quick else 0.8)
    elif suite == "async":
        from benchmarks import async_bench
        async_bench.run(rounds=8 if quick else 20,
                        ticks=32 if quick else 100,
                        target=0.5 if quick else 0.8)
    elif suite == "robust":
        from benchmarks import robust_bench
        robust_bench.run(rounds=12 if quick else 20, target=0.7)
    elif suite == "scale":
        from benchmarks import scale_bench
        scale_bench.run(rounds=8 if quick else 16, quick=quick)
    else:
        raise ValueError(f"unknown suite {suite!r}")


def dump_suite_json(path: str, suite: str, rows: list[dict],
                    quick: bool) -> None:
    """Write one suite's structured rows as a ``BENCH_<suite>.json``
    artifact.  Everything except ``us_per_call``/``spread_us`` is
    deterministic for a fixed seed (no timestamps, no host info), so two
    runs differ only in the timing fields — pinned by
    tests/test_bench.py."""
    doc = {"schema": BENCH_SCHEMA_VERSION, "suite": suite, "quick": quick,
           "rows": rows}
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--suite", action="append", choices=SUITES)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--dump-json", metavar="DIR", default=None,
                    help="persist each suite's rows as DIR/BENCH_<suite>.json")
    args = ap.parse_args(argv)
    suites = args.suite or list(SUITES)
    if args.dump_json:
        os.makedirs(args.dump_json, exist_ok=True)

    from benchmarks import common

    print("name,us_per_call,derived")
    for suite in SUITES:
        if suite not in suites:
            continue
        start = len(common.ROWS)
        _run_suite(suite, args.quick)
        if args.dump_json:
            dump_suite_json(
                os.path.join(args.dump_json, f"BENCH_{suite}.json"),
                suite, common.ROWS[start:], args.quick)
    return 0


if __name__ == "__main__":
    sys.exit(main())
