"""Benchmark suite entry point — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (see benchmarks/common.py).
``--quick`` shrinks round counts for CI; default sizes reproduce the
paper's qualitative orderings.
"""
from __future__ import annotations

import argparse
import sys


SUITES = ("table1", "table2", "table345", "fig3", "kernels", "arch_step",
          "roofline", "participation", "comm", "net")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--suite", action="append", choices=SUITES)
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args(argv)
    suites = args.suite or list(SUITES)

    print("name,us_per_call,derived")
    if "table1" in suites:
        from benchmarks import table1_accuracy
        table1_accuracy.run(rounds=15 if args.quick else 40)
    if "table2" in suites:
        from benchmarks import table2_topology
        table2_topology.run(rounds=12 if args.quick else 30)
    if "table345" in suites:
        from benchmarks import table345_convergence
        table345_convergence.run(max_rounds=16 if args.quick else 40,
                                 target=0.6 if args.quick else 0.7)
    if "fig3" in suites:
        from benchmarks import fig3_ablations
        fig3_ablations.run(rounds=10 if args.quick else 25)
    if "kernels" in suites:
        from benchmarks import kernels_bench
        kernels_bench.run()
    if "arch_step" in suites:
        from benchmarks import arch_step_bench
        archs = ("llama3-8b", "mixtral-8x7b", "falcon-mamba-7b",
                 "zamba2-1.2b") if args.quick else None
        arch_step_bench.run(archs)
    if "roofline" in suites:
        from benchmarks import roofline_report
        roofline_report.run()
    if "participation" in suites:
        from benchmarks import participation_bench
        participation_bench.run(rounds=10 if args.quick else 20)
    if "comm" in suites:
        from benchmarks import comm_bench
        comm_bench.run(rounds=10 if args.quick else 20,
                       target=0.5 if args.quick else 0.6)
    if "net" in suites:
        from benchmarks import net_bench
        net_bench.run(rounds=10 if args.quick else 20,
                      target=0.5 if args.quick else 0.8)
    return 0


if __name__ == "__main__":
    sys.exit(main())
