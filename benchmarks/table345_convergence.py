"""Paper Tables 3-5: rounds needed to reach a target accuracy (the
convergence-speed comparison)."""
from benchmarks.common import emit, run_dfl

ALGOS = ("dpsgd", "dfedavg", "dfedavgm", "dfedsam", "dfedadmm",
         "dfedadmm_sam")


def run(max_rounds: int = 40, target: float = 0.70, m: int = 16):
    results = {}
    for alpha_name, alpha in (("dir0.1", 0.1), ("dir0.3", 0.3),
                              ("iid", None)):
        for algo in ALGOS:
            kw = {"lam": 1.0, "topology": "ring"} if "admm" in algo else \
                {"topology": "ring"}
            _, hist, us = run_dfl(algo, rounds=max_rounds, alpha=alpha, m=m,
                                  eval_every=2, **kw)
            ev = hist["eval"]
            rounds_needed = f">{max_rounds}"
            for r, a in zip(ev["round"], ev["acc"]):
                if a >= target:
                    rounds_needed = r + 1
                    break
            emit(f"table345/{alpha_name}/acc@{target}/{algo}", us,
                 f"rounds={rounds_needed}")
            results[(alpha_name, algo)] = rounds_needed
    return results
