"""Async execution engine: event-driven ticks vs synchronous rounds.

The net suite (``net_bench``) prices synchronous rounds: every round
ends when the slowest active client has heard all its in-neighbours, so
one straggler link taxes the whole federation.  The deadline
participation mode caps that tax by masking slow clients — at the cost
of freezing them out (on ``wan-lan`` a deadline below the cross-site
transfer permanently excludes half the federation and the run never
reaches the target).

This suite runs the third option: the event-driven engine
(``repro.core.async_engine``).  Each client re-enters the gossip as soon
as its own modeled compute + transfer completes; fast clients tick every
window while stragglers tick at their own rate, mixing against
bounded-staleness buffers.  Three rows per preset:

* ``sync-full``  — classic synchronous rounds, everyone waits.
* ``deadline``   — synchronous rounds with the deadline mask (the
  per-preset deadline is tuned to the largest value that still causes
  partial participation while converging).
* ``async``      — the event engine (``tick_s``/``max_staleness``).

The headline metric is modeled time-to-target (cumulative ``sim_time``
until the eval accuracy first reaches ``target``): on both heterogeneous
presets async dfedadmm reaches the target in less modeled wall-clock
than the best synchronous deadline configuration.
"""
from benchmarks.common import (emit, rounds_from_history, run_dfl,
                               time_from_history)

from repro.core import ParticipationSpec

# (preset, tuned sync deadline): largest deadline that still masks slow
# links without freezing the federation (see module docstring)
PRESETS = (("lognormal", 0.08), ("wan-lan", 0.13))

TICK_S = 0.02
MAX_STALENESS = 8


def _fmt(v, suffix=""):
    return "-" if v is None else f"{v:.3f}{suffix}"


def run(rounds: int = 20, ticks: int = 100, m: int = 16,
        target: float = 0.8):
    for preset, deadline in PRESETS:
        common = dict(rounds=rounds, alpha=0.3, m=m, topology="ring",
                      eval_every=1, network=preset)

        acc, hist, us = run_dfl("dfedadmm", **common)
        rt = rounds_from_history(hist, target)
        emit(f"async/sync-full/{preset}", us,
             f"acc={acc:.4f};"
             f"rounds_to_{target:g}={rt if rt is not None else f'>{rounds}'};"
             f"time_to_{target:g}={_fmt(time_from_history(hist, target), 's')};"
             f"sim_s_per_round={sum(hist['sim_time']) / rounds:.4f}")

        part = ParticipationSpec(mode="deadline", deadline=deadline)
        acc, hist, us = run_dfl("dfedadmm", participation=part, **common)
        rt = rounds_from_history(hist, target)
        emit(f"async/deadline{deadline:g}s/{preset}", us,
             f"acc={acc:.4f};"
             f"rounds_to_{target:g}={rt if rt is not None else f'>{rounds}'};"
             f"time_to_{target:g}={_fmt(time_from_history(hist, target), 's')};"
             f"sim_s_per_round={sum(hist['sim_time']) / rounds:.4f};"
             f"participation={sum(hist['participation']) / rounds:.2f}")

        acc, hist, us = run_dfl("dfedadmm", rounds=ticks, alpha=0.3, m=m,
                                topology="ring", eval_every=2,
                                network=preset, execution="async",
                                tick_s=TICK_S, max_staleness=MAX_STALENESS)
        tt = rounds_from_history(hist, target)
        emit(f"async/async/{preset}", us,
             f"acc={acc:.4f};"
             f"ticks_to_{target:g}={tt if tt is not None else f'>{ticks}'};"
             f"time_to_{target:g}={_fmt(time_from_history(hist, target), 's')};"
             f"sim_s_per_tick={sum(hist['sim_time']) / ticks:.4f};"
             f"mean_ticked={sum(hist['ticked']) / ticks:.2f};"
             f"max_staleness={max(hist['staleness'])}")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
