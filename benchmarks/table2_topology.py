"""Paper Table 2 / Fig. 2: accuracy per deterministic topology (Ring,
Grid, Exp, Full) for the decentralized methods, plus measured spectral
gaps (Definition 1)."""
from repro.core import make_gossip

from benchmarks.common import emit, run_dfl

TOPOLOGIES = ("ring", "grid", "exp", "full")
ALGOS = ("dpsgd", "dfedavg", "dfedavgm", "dfedsam", "dfedadmm",
         "dfedadmm_sam")


def run(rounds: int = 30, m: int = 16):
    for topo in TOPOLOGIES:
        psi = make_gossip(topo, m).psi
        emit(f"table2/psi/{topo}", 0.0, f"psi={psi:.4f}")
    results = {}
    for topo in TOPOLOGIES:
        for algo in ALGOS:
            kw = {"lam": 1.0} if "admm" in algo else {}
            acc, _, us = run_dfl(algo, rounds=rounds, alpha=0.1,
                                 topology=topo, m=m, **kw)
            emit(f"table2/{topo}/{algo}", us, f"acc={acc:.4f}")
            results[(topo, algo)] = acc
    return results
