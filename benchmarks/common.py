"""Shared harness for the benchmark suite.

Output contract (benchmarks/run.py): one CSV line per measurement,
``name,us_per_call,derived`` where ``derived`` is the benchmark-specific
quality metric (accuracy, rounds-to-target, psi, bytes, ...).  ``emit``
also appends a structured record to ``ROWS`` so ``benchmarks/run.py
--dump-json`` can persist every suite as a schema'd ``BENCH_<suite>.json``
artifact (compared against the committed baselines by
``tools/bench_compare.py`` in CI).

Timing convention: ``time_stats`` measures median-of-N with warmup and
reports the spread (IQR) alongside, so a single scheduler hiccup cannot
move the number a CI gate sees; ``run_dfl``/``run_cfl`` report the
steady-state us/round (median over post-compile rounds from
``history["wall_us"]``), not total-wall/rounds, which was dominated by
the one-off jit compile.
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

# structured measurement records, appended by emit(): one dict per CSV
# row — {"name", "us_per_call", "spread_us" (None when the measurement
# carries no repeat statistics), "derived"}
ROWS: list[dict] = []


def emit(name: str, us_per_call: float, derived,
         spread_us: float | None = None) -> None:
    ROWS.append({"name": name, "us_per_call": float(us_per_call),
                 "spread_us": None if spread_us is None else float(spread_us),
                 "derived": str(derived)})
    print(f"{name},{us_per_call:.1f},{derived}")


def time_stats(fn, *args, warmup: int = 2, iters: int = 7) -> dict:
    """Repeat-timing statistics for ``fn(*args)`` (blocking on outputs):
    ``{"median_us", "spread_us", "min_us", "iters", "warmup"}`` with
    ``spread_us`` the interquartile range — the noise scale a regression
    threshold has to clear (``tools/bench_compare.py``)."""
    if warmup < 1 or iters < 1:
        raise ValueError(f"need warmup >= 1 and iters >= 1, "
                         f"got {warmup=}, {iters=}")
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    us = np.asarray(ts) * 1e6
    q25, med, q75 = np.percentile(us, [25, 50, 75])
    return {"median_us": float(med), "spread_us": float(q75 - q25),
            "min_us": float(us.min()), "iters": iters, "warmup": warmup}


def time_fn(fn, *args, warmup: int = 2, iters: int = 7) -> float:
    """Median wall-time per call in microseconds (median-of-``iters``
    after ``warmup`` discarded calls; use ``time_stats`` for the spread)."""
    return time_stats(fn, *args, warmup=warmup, iters=iters)["median_us"]


def steady_state_us(hist: dict) -> tuple[float, float]:
    """(median, IQR) of the post-compile per-round wall time from
    ``history["wall_us"]`` — round 0 pays the jit compile and is
    excluded whenever there is more than one round."""
    wall = hist.get("wall_us") or []
    if not wall:
        return float("nan"), 0.0
    steady = wall[1:] if len(wall) > 1 else wall
    q25, med, q75 = np.percentile(np.asarray(steady), [25, 50, 75])
    return float(med), float(q75 - q25)


# ---------------------------------------------------------------------------
# The paper's experimental substrate (synthetic; see DESIGN.md §2)
# ---------------------------------------------------------------------------

def mlp_init(dim, n_classes, hidden=48, seed=0):
    rng = np.random.default_rng(seed)
    return {
        "w1": jnp.asarray(rng.normal(size=(dim, hidden)) / np.sqrt(dim),
                          jnp.float32),
        "b1": jnp.zeros(hidden),
        "w2": jnp.asarray(rng.normal(size=(hidden, n_classes)) /
                          np.sqrt(hidden), jnp.float32),
        "b2": jnp.zeros(n_classes),
    }


def mlp_logits(params, x):
    h = jnp.tanh(x @ params["w1"] + params["b1"])
    return h @ params["w2"] + params["b2"]


def ce_loss(params, batch, rng):
    logits = mlp_logits(params, batch["x"])
    logz = jax.nn.logsumexp(logits, -1)
    gold = jnp.take_along_axis(logits, batch["y"][..., None], -1)[..., 0]
    return jnp.mean(logz - gold)


@functools.lru_cache(maxsize=4)
def fl_task(noise: float = 1.0, seed: int = 0):
    from repro.data.synthetic import SyntheticClassification
    return SyntheticClassification(n_classes=10, dim=24, n_train=8000,
                                   n_test=2000, noise=noise, seed=seed)


def accuracy(params, task) -> float:
    logits = mlp_logits(params, jnp.asarray(task.x_test))
    return float(np.mean(np.argmax(np.asarray(logits), -1) == task.y_test))


def run_dfl(algo: str, *, rounds: int, alpha, topology="random", m=16, K=5,
            lr=0.1, lam=0.2, rho=0.05, seed=0, eval_every=5,
            participation=None, transport="", codec="identity",
            codec_bits=8, codec_k=64, use_kernel=False, network=None,
            execution="sync", tick_s=0.0, max_staleness=4,
            threat=None, robust="mean", robust_trim=0.25,
            dp_clip=1.0, dp_noise=0.0, n_virtual=0, clusters=0):
    """Run a DFL algorithm on the synthetic federated task; returns
    (final_acc, history, us_per_round) — us_per_round is the
    steady-state median over post-compile rounds (``steady_state_us``).
    ``participation`` is an optional ``repro.core.ParticipationSpec``
    scenario (default: every client, every round); ``transport``/
    ``codec``/``use_kernel`` select the communication layer
    (``repro.core.comm``; ``use_kernel`` dispatches the fused Pallas
    round, including the fused quantized-gossip kernel on the dense
    path) — the history carries per-round wire bytes — and ``network`` a
    cost-model preset (``repro.core.network``) — the history then also
    carries per-round modeled wall-clock seconds.  ``execution="async"``
    (with ``tick_s``/``max_staleness``) runs the event-driven engine
    (``repro.core.async_engine``); ``rounds`` then counts ticks."""
    from repro.core import (DFLConfig, ParticipationSpec, mean_params,
                            simulate)
    task = fl_task()
    parts = task.partition(m, alpha, seed=seed)
    sampler0 = task.client_sampler(parts, batch=32, K=K, seed=seed)

    def sampler(t):
        b = sampler0(t)
        return {"x": jnp.asarray(b["x"]), "y": jnp.asarray(b["y"])}

    cfg = DFLConfig(algorithm=algo, m=m, K=K, topology=topology, lr=lr,
                    lam=lam, rho=rho, degree=min(10, m - 1),
                    transport=transport, codec=codec,
                    codec_bits=codec_bits, codec_k=codec_k,
                    use_kernel=use_kernel,
                    participation=participation or ParticipationSpec(),
                    network=network, execution=execution, tick_s=tick_s,
                    max_staleness=max_staleness, threat=threat,
                    robust=robust, robust_trim=robust_trim,
                    dp_clip=dp_clip, dp_noise=dp_noise,
                    n_virtual=n_virtual, clusters=clusters)
    params = mlp_init(task.dim, task.n_classes, seed=seed)

    def eval_fn(p):
        return {"acc": accuracy(p, task)}

    state, hist = simulate(ce_loss, eval_fn, params, cfg, sampler,
                           rounds=rounds, seed=seed, eval_every=eval_every)
    final_acc = accuracy(mean_params(state.params), task)
    us, _ = steady_state_us(hist)
    return final_acc, hist, us


def run_cfl(algo: str, *, rounds: int, alpha, m=16, K=5, lr=0.1, seed=0):
    from repro.core import CFLConfig, simulate_cfl
    task = fl_task()
    parts = task.partition(m, alpha, seed=seed)
    sampler0 = task.client_sampler(parts, batch=32, K=K, seed=seed)

    def sampler(t, ids):
        b = sampler0(t)
        return {"x": jnp.asarray(b["x"][ids]), "y": jnp.asarray(b["y"][ids])}

    cfg = CFLConfig(algorithm=algo, m=m, participation=0.25, K=K, lr=lr)
    params = mlp_init(task.dim, task.n_classes, seed=seed)
    state, hist = simulate_cfl(ce_loss, None, params, cfg, sampler,
                               rounds=rounds, seed=seed)
    us, _ = steady_state_us(hist)
    return accuracy(state.global_params, task), hist, us


def rounds_from_history(hist, target):
    """Rounds until the eval accuracy in ``hist`` first reaches
    ``target`` (None if it never does)."""
    ev = hist.get("eval", {})
    for r, a in zip(ev.get("round", []), ev.get("acc", [])):
        if a >= target:
            return r + 1
    return None


def time_from_history(hist, target):
    """Modeled wall-clock seconds (cumulative ``sim_time``) until the
    eval accuracy first reaches ``target`` — the metric rounds and bytes
    cannot see (None if the run has no network model or never gets
    there)."""
    sim = hist.get("sim_time")
    if sim is None:
        return None
    r = rounds_from_history(hist, target)
    if r is None:
        return None
    return float(sum(sim[:r]))


def rounds_to_accuracy(algo, target, *, alpha, max_rounds, kind="dfl", **kw):
    """Paper Tables 3-5 metric: rounds until test accuracy >= target."""
    if kind == "dfl":
        _, hist, _ = run_dfl(algo, rounds=max_rounds, alpha=alpha,
                             eval_every=2, **kw)
        ev = hist["eval"]
    else:
        acc, hist, _ = run_cfl(algo, rounds=max_rounds, alpha=alpha, **kw)
        return max_rounds  # cfl history has no per-round acc; unused path
    for r, a in zip(ev["round"], ev["acc"]):
        if a >= target:
            return r + 1
    return f">{max_rounds}"
