"""Shared harness for the benchmark suite.

Output contract (benchmarks/run.py): one CSV line per measurement,
``name,us_per_call,derived`` where ``derived`` is the benchmark-specific
quality metric (accuracy, rounds-to-target, psi, bytes, ...).
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

ROWS: list[tuple[str, float, str]] = []


def emit(name: str, us_per_call: float, derived) -> None:
    ROWS.append((name, us_per_call, str(derived)))
    print(f"{name},{us_per_call:.1f},{derived}")


def time_fn(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall-time per call in microseconds (blocking on outputs)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


# ---------------------------------------------------------------------------
# The paper's experimental substrate (synthetic; see DESIGN.md §2)
# ---------------------------------------------------------------------------

def mlp_init(dim, n_classes, hidden=48, seed=0):
    rng = np.random.default_rng(seed)
    return {
        "w1": jnp.asarray(rng.normal(size=(dim, hidden)) / np.sqrt(dim),
                          jnp.float32),
        "b1": jnp.zeros(hidden),
        "w2": jnp.asarray(rng.normal(size=(hidden, n_classes)) /
                          np.sqrt(hidden), jnp.float32),
        "b2": jnp.zeros(n_classes),
    }


def mlp_logits(params, x):
    h = jnp.tanh(x @ params["w1"] + params["b1"])
    return h @ params["w2"] + params["b2"]


def ce_loss(params, batch, rng):
    logits = mlp_logits(params, batch["x"])
    logz = jax.nn.logsumexp(logits, -1)
    gold = jnp.take_along_axis(logits, batch["y"][..., None], -1)[..., 0]
    return jnp.mean(logz - gold)


@functools.lru_cache(maxsize=4)
def fl_task(noise: float = 1.0, seed: int = 0):
    from repro.data.synthetic import SyntheticClassification
    return SyntheticClassification(n_classes=10, dim=24, n_train=8000,
                                   n_test=2000, noise=noise, seed=seed)


def accuracy(params, task) -> float:
    logits = mlp_logits(params, jnp.asarray(task.x_test))
    return float(np.mean(np.argmax(np.asarray(logits), -1) == task.y_test))


def run_dfl(algo: str, *, rounds: int, alpha, topology="random", m=16, K=5,
            lr=0.1, lam=0.2, rho=0.05, seed=0, eval_every=5,
            participation=None, transport="", codec="identity",
            codec_bits=8, codec_k=64, network=None):
    """Run a DFL algorithm on the synthetic federated task; returns
    (final_acc, history, us_per_round).  ``participation`` is an optional
    ``repro.core.ParticipationSpec`` scenario (default: every client,
    every round); ``transport``/``codec`` select the communication layer
    (``repro.core.comm``) — the history carries per-round wire bytes —
    and ``network`` a cost-model preset (``repro.core.network``) — the
    history then also carries per-round modeled wall-clock seconds."""
    from repro.core import (DFLConfig, ParticipationSpec, mean_params,
                            simulate)
    task = fl_task()
    parts = task.partition(m, alpha, seed=seed)
    sampler0 = task.client_sampler(parts, batch=32, K=K, seed=seed)

    def sampler(t):
        b = sampler0(t)
        return {"x": jnp.asarray(b["x"]), "y": jnp.asarray(b["y"])}

    cfg = DFLConfig(algorithm=algo, m=m, K=K, topology=topology, lr=lr,
                    lam=lam, rho=rho, degree=min(10, m - 1),
                    transport=transport, codec=codec,
                    codec_bits=codec_bits, codec_k=codec_k,
                    participation=participation or ParticipationSpec(),
                    network=network)
    params = mlp_init(task.dim, task.n_classes, seed=seed)

    def eval_fn(p):
        return {"acc": accuracy(p, task)}

    t0 = time.perf_counter()
    state, hist = simulate(ce_loss, eval_fn, params, cfg, sampler,
                           rounds=rounds, seed=seed, eval_every=eval_every)
    dt = time.perf_counter() - t0
    final_acc = accuracy(mean_params(state.params), task)
    return final_acc, hist, dt / rounds * 1e6


def run_cfl(algo: str, *, rounds: int, alpha, m=16, K=5, lr=0.1, seed=0):
    from repro.core import CFLConfig, simulate_cfl
    task = fl_task()
    parts = task.partition(m, alpha, seed=seed)
    sampler0 = task.client_sampler(parts, batch=32, K=K, seed=seed)

    def sampler(t, ids):
        b = sampler0(t)
        return {"x": jnp.asarray(b["x"][ids]), "y": jnp.asarray(b["y"][ids])}

    cfg = CFLConfig(algorithm=algo, m=m, participation=0.25, K=K, lr=lr)
    params = mlp_init(task.dim, task.n_classes, seed=seed)
    t0 = time.perf_counter()
    state, hist = simulate_cfl(ce_loss, None, params, cfg, sampler,
                               rounds=rounds, seed=seed)
    dt = time.perf_counter() - t0
    return accuracy(state.global_params, task), hist, dt / rounds * 1e6


def rounds_from_history(hist, target):
    """Rounds until the eval accuracy in ``hist`` first reaches
    ``target`` (None if it never does)."""
    ev = hist.get("eval", {})
    for r, a in zip(ev.get("round", []), ev.get("acc", [])):
        if a >= target:
            return r + 1
    return None


def time_from_history(hist, target):
    """Modeled wall-clock seconds (cumulative ``sim_time``) until the
    eval accuracy first reaches ``target`` — the metric rounds and bytes
    cannot see (None if the run has no network model or never gets
    there)."""
    sim = hist.get("sim_time")
    if sim is None:
        return None
    r = rounds_from_history(hist, target)
    if r is None:
        return None
    return float(sum(sim[:r]))


def rounds_to_accuracy(algo, target, *, alpha, max_rounds, kind="dfl", **kw):
    """Paper Tables 3-5 metric: rounds until test accuracy >= target."""
    if kind == "dfl":
        _, hist, _ = run_dfl(algo, rounds=max_rounds, alpha=alpha,
                             eval_every=2, **kw)
        ev = hist["eval"]
    else:
        acc, hist, _ = run_cfl(algo, rounds=max_rounds, alpha=alpha, **kw)
        return max_rounds  # cfl history has no per-round acc; unused path
    for r, a in zip(ev["round"], ev["acc"]):
        if a >= target:
            return r + 1
    return f">{max_rounds}"
