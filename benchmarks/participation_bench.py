"""Partial-participation scenarios: round cost, accuracy,
rounds-to-target, and wire bytes vs the fraction of clients that
actually gossip each round.

Two effects compose: fewer active clients means less useful work per
round (slower convergence in rounds), but on the simulation substrate
the jitted round still computes all m clients and masks, so us/round is
roughly flat — the derived columns make the compute/communication
trade-off visible.  Each participation row also reports rounds until the
eval accuracy reaches ``target`` and the modeled per-round uplink bytes
(active clients x codec message size), so participation and compression
land in one table (see ``experiments/update_tables.py``); the codec rows
at the bottom cross 50% participation with compressed messages — the
bandwidth-limited-client scenario.  Dropout and straggler rows quantify
the scenarios the paper's full-participation setting never sees.
"""
import numpy as np

from repro.core import ParticipationSpec
from repro.core.gossip import mask_and_renormalize, make_gossip, spectral_psi
from repro.core.participation import participation_schedule

from benchmarks.common import emit, rounds_from_history, run_dfl

RATES = (1.0, 0.75, 0.5, 0.25)


def run(rounds: int = 20, m: int = 16, algo: str = "dfedadmm",
        target: float = 0.6):
    # effective connectivity among the participants: psi of the active
    # principal submatrix of the masked matrix, averaged over sampled
    # rounds (the full masked matrix always has psi == 1 once anyone sits
    # out — identity rows — so the submatrix is the informative number)
    base = make_gossip("random", m, degree=min(10, m - 1))
    for p in RATES:
        spec = ParticipationSpec(mode="fraction", p=p)
        sched = participation_schedule(spec, m, rounds, K=5)
        psis = []
        for rp in sched:
            wm = mask_and_renormalize(base.matrix, rp.active)
            sub = wm[np.ix_(rp.active, rp.active)]
            psis.append(spectral_psi(sub))
        emit(f"participation/psi/p{p:g}", 0.0,
             f"mean_active_psi={sum(psis) / len(psis):.4f}")

    def _row(name, part, **kw):
        acc, hist, us = run_dfl(algo, rounds=rounds, alpha=0.3, m=m,
                                participation=part, eval_every=2, **kw)
        rt = rounds_from_history(hist, target)
        bpr = int(np.mean(hist["wire_bytes"]))
        emit(f"participation/{algo}/{name}", us,
             f"acc={acc:.4f};loss={hist['loss'][-1]:.4f};"
             f"rounds_to_{target:g}={rt if rt is not None else f'>{rounds}'};"
             f"bytes_per_round={bpr}")

    for p in RATES:
        part = (ParticipationSpec() if p == 1.0
                else ParticipationSpec(mode="fraction", p=p))
        _row(f"p{p:g}", part)

    for name, part in (
        ("dropout0.2", ParticipationSpec(mode="uniform", p=0.8, dropout=0.2)),
        ("stragglers", ParticipationSpec(straggler_frac=0.5,
                                         straggler_steps=1)),
    ):
        _row(name, part)

    # participation x compression: half the clients, compressed messages
    # (the bandwidth-limited-client scenario of arXiv:2107.12048)
    half = ParticipationSpec(mode="fraction", p=0.5)
    _row("p0.5+int8", half, codec="int8")
    _row("p0.5+int4", half, codec="int8", codec_bits=4)

    # variance reduction at sparse participation: 10% of clients per
    # round is where plain gossip SGD starts paying for client drift —
    # the control-variate (scaffold), gradient-tracking (dfedtrack), and
    # adaptive-penalty (dfedadmm_adaptive) solvers correct the drift
    # with one extra gossip-carried message (scaffold/dfedtrack double
    # bytes_per_round; the table makes that trade visible)
    sparse = ParticipationSpec(mode="fraction", p=0.1)
    for vr_algo in ("dfedavg", "dpsgd", "scaffold", "dfedtrack",
                    "dfedadmm_adaptive"):
        acc, hist, us = run_dfl(vr_algo, rounds=rounds, alpha=0.3, m=m,
                                participation=sparse, eval_every=2)
        rt = rounds_from_history(hist, target)
        bpr = int(np.mean(hist["wire_bytes"]))
        emit(f"participation/{vr_algo}/p0.1", us,
             f"acc={acc:.4f};loss={hist['loss'][-1]:.4f};"
             f"rounds_to_{target:g}={rt if rt is not None else f'>{rounds}'};"
             f"bytes_per_round={bpr}")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
