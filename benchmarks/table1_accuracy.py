"""Paper Table 1: top-1 accuracy across IID / Dir(0.6) / Dir(0.3) for all
DFL + CFL methods (synthetic federated task — offline stand-in for
MNIST/CIFAR; see DESIGN.md §2)."""
from benchmarks.common import emit, run_cfl, run_dfl

DFL_ALGOS = ("dpsgd", "dfedavg", "dfedavgm", "dfedsam", "dfedadmm",
             "dfedadmm_sam")
CFL_ALGOS = ("fedavg", "fedsam", "fedpd")
PARTITIONS = (("iid", None), ("dir0.6", 0.6), ("dir0.3", 0.3),
              ("dir0.1", 0.1))


def run(rounds: int = 40, m: int = 16):
    results = {}
    for pname, alpha in PARTITIONS:
        for algo in DFL_ALGOS:
            kw = {"lam": 1.0} if "admm" in algo else {}
            acc, _, us = run_dfl(algo, rounds=rounds, alpha=alpha, m=m, **kw)
            emit(f"table1/{pname}/{algo}", us, f"acc={acc:.4f}")
            results[(pname, algo)] = acc
        for algo in CFL_ALGOS:
            acc, _, us = run_cfl(algo, rounds=rounds, alpha=alpha, m=m)
            emit(f"table1/{pname}/{algo}", us, f"acc={acc:.4f}")
            results[(pname, algo)] = acc
    return results
