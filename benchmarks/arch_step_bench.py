"""Per-architecture DFL round / decode step wall time on the reduced
(smoke) configs — CPU-scale sanity numbers for the framework overheads."""
import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_smoke_config
from repro.core import DFLConfig, init_state, make_gossip, make_train_round
from repro.data.synthetic import make_model_batch
from repro.models import build_model

from benchmarks.common import emit, time_fn


def run(archs=None):
    archs = archs or ARCH_IDS
    for arch in archs:
        cfg = get_smoke_config(arch)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        m, K, B, S = 4, 2, 2, 32
        dfl = DFLConfig(algorithm="dfedadmm", m=m, K=K, topology="ring")
        spec = make_gossip("ring", m)
        round_fn = jax.jit(make_train_round(model.loss, dfl, spec=spec))
        state = init_state(params, dfl)
        batch = jax.tree.map(jnp.asarray,
                             make_model_batch(cfg, B, S, lead=(m, K)))
        w = jnp.asarray(spec.matrix, jnp.float32)
        us = time_fn(lambda s, b, w_: round_fn(s, b, w_)[0], state, batch, w,
                     warmup=1, iters=3)
        tokens = m * K * B * S
        emit(f"arch_step/dfl_round/{arch}", us,
             f"tok_per_s={tokens / (us / 1e6):.0f}")
