"""Paper Fig. 3 ablations: local steps K, penalty lambda, clients m,
perturbation radius rho."""
from benchmarks.common import emit, run_dfl


def run(rounds: int = 25):
    for K in (1, 2, 5, 10):
        acc, _, us = run_dfl("dfedadmm", rounds=rounds, alpha=0.3, K=K)
        emit(f"fig3/K={K}", us, f"acc={acc:.4f}")
    for lam in (0.05, 0.1, 0.2, 0.5):
        acc, _, us = run_dfl("dfedadmm", rounds=rounds, alpha=0.3, lam=lam)
        emit(f"fig3/lambda={lam}", us, f"acc={acc:.4f}")
    for m in (8, 16, 32):
        acc, _, us = run_dfl("dfedadmm", rounds=rounds, alpha=0.3, m=m)
        emit(f"fig3/m={m}", us, f"acc={acc:.4f}")
    for rho in (0.01, 0.05, 0.1, 0.2):
        acc, _, us = run_dfl("dfedadmm_sam", rounds=rounds, alpha=0.3,
                             rho=rho)
        emit(f"fig3/rho={rho}", us, f"acc={acc:.4f}")
