"""Network cost model: time-to-target-accuracy across codecs and presets.

The comm suite (``comm_bench``) measures *bytes* vs *rounds*; this suite
measures what heterogeneous real networks actually cost — *time*.  Each
row runs one (algorithm, codec, network preset) point through the
``repro.core.network`` cost model and reports:

* rounds until the eval accuracy reaches ``target`` (the old metric),
* modeled wall-clock seconds until target (cumulative per-round
  ``sim_time``: K x compute + the slowest active in-neighbour link), and
* the modeled bytes per round.

The point of the suite: on a bandwidth-starved preset the time-to-target
ranking *reorders* the rounds-to-target ranking — a codec that pays a
round-count penalty for its compression can still win the wall-clock
race, which is invisible to rounds and bytes alone (e.g. on ``wan-lan``
the 4-bit codec loses a round to the identity wire at the 0.8 target
and still finishes ~3x sooner on the modeled clock).

The deadline rows close the loop: ``ParticipationSpec(mode="deadline")``
masks the clients whose modeled transfer misses the round deadline, so
slow links cause partial participation (arXiv:2107.12048's
communication/computing balancing, composed with the masked round).
"""
from benchmarks.common import (emit, rounds_from_history, run_dfl,
                               time_from_history)

from repro.core import ParticipationSpec

PRESETS = ("uniform", "lognormal", "wan-lan")

CODEC_POINTS = (
    ("identity", dict()),
    ("int8", dict(codec="int8", codec_bits=8)),
    ("int4", dict(codec="int8", codec_bits=4)),
    ("rand256", dict(codec="randk", codec_k=256)),
)


def _fmt(v, suffix=""):
    return "-" if v is None else f"{v:.3f}{suffix}"


def run(rounds: int = 20, m: int = 16, target: float = 0.8,
        deadline: float = 0.08):
    for algo in ("dfedadmm", "dfedavg"):
        for preset in PRESETS:
            for cname, kw in CODEC_POINTS:
                acc, hist, us = run_dfl(algo, rounds=rounds, alpha=0.3, m=m,
                                        topology="ring", eval_every=1,
                                        network=preset, **kw)
                rt = rounds_from_history(hist, target)
                tt = time_from_history(hist, target)
                emit(f"net/{algo}/{cname}/{preset}", us,
                     f"acc={acc:.4f};"
                     f"rounds_to_{target:g}="
                     f"{rt if rt is not None else f'>{rounds}'};"
                     f"time_to_{target:g}={_fmt(tt, 's')};"
                     f"sim_s_per_round={sum(hist['sim_time']) / rounds:.4f};"
                     f"bytes_per_round={hist['wire_bytes'][0]}")

    # variance-reduction solvers on the bandwidth-starved preset: the
    # tracking family (scaffold / dfedtrack) ships a second
    # full-precision gossip message per round — bytes_per_round and the
    # modeled clock both double vs dfedavg — while dfedadmm_adaptive
    # pays nothing on the wire.  The rows make the accuracy-per-second
    # trade of drift correction visible under a real network model.
    for algo in ("scaffold", "dfedtrack", "dfedadmm_adaptive"):
        for cname, kw in (("identity", dict()),
                          ("int8", dict(codec="int8", codec_bits=8))):
            acc, hist, us = run_dfl(algo, rounds=rounds, alpha=0.3, m=m,
                                    topology="ring", eval_every=1,
                                    network="wan-lan", **kw)
            rt = rounds_from_history(hist, target)
            tt = time_from_history(hist, target)
            emit(f"net/{algo}/{cname}/wan-lan", us,
                 f"acc={acc:.4f};"
                 f"rounds_to_{target:g}="
                 f"{rt if rt is not None else f'>{rounds}'};"
                 f"time_to_{target:g}={_fmt(tt, 's')};"
                 f"sim_s_per_round={sum(hist['sim_time']) / rounds:.4f};"
                 f"bytes_per_round={hist['wire_bytes'][0]}")

    # deadline participation: the network model *drives* the mask — on the
    # heterogeneous presets the slow-linked clients sit rounds out
    for preset in ("lognormal", "wan-lan"):
        part = ParticipationSpec(mode="deadline", deadline=deadline)
        acc, hist, us = run_dfl("dfedadmm", rounds=rounds, alpha=0.3, m=m,
                                topology="ring", eval_every=1,
                                network=preset, participation=part)
        rt = rounds_from_history(hist, target)
        tt = time_from_history(hist, target)
        mean_p = sum(hist["participation"]) / rounds
        emit(f"net/deadline{deadline:g}s/identity/{preset}", us,
             f"acc={acc:.4f};"
             f"rounds_to_{target:g}={rt if rt is not None else f'>{rounds}'};"
             f"time_to_{target:g}={_fmt(tt, 's')};"
             f"sim_s_per_round={sum(hist['sim_time']) / rounds:.4f};"
             f"bytes_per_round={int(sum(hist['wire_bytes']) / rounds)};"
             f"participation={mean_p:.2f}")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
