"""Kernel micro-benchmarks: fused Pallas ops (interpret mode on CPU — a
correctness-speed proxy, not TPU wall time) vs the jnp reference, plus
the fused quantized-gossip kernel against its composed
quantize -> dequantize -> mix chain.

``quick=True`` is the CI smoke subset: one size per kernel and no
selective scan (interpret mode makes it a Python loop), so the PR perf
job finishes in seconds; row names carry their sizes, and the committed
baseline ``benchmarks/baselines/BENCH_kernels.json`` is the quick
variant the CI gate compares against.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref

from benchmarks.common import emit, time_stats


def _emit_timed(name, fn, *args, derived="oracle"):
    st = time_stats(fn, *args)
    emit(name, st["median_us"], derived, spread_us=st["spread_us"])
    return st


def run(quick: bool = False):
    rng = np.random.default_rng(0)
    sizes = (1 << 16,) if quick else (1 << 16, 1 << 20)
    for n in sizes:
        x, g, d, a = (jnp.asarray(rng.normal(size=n), jnp.float32)
                      for _ in range(4))
        f_ref = jax.jit(lambda x, g, d, a: ref.admm_update(
            x, g, d, a, lr=0.1, lam=0.2))
        _emit_timed(f"kernel/admm_update/jnp/n={n}", f_ref, x, g, d, a)
        f_k = jax.jit(lambda x, g, d, a: ops.admm_update(
            x, g, d, a, lr=0.1, lam=0.2))
        err = float(jnp.max(jnp.abs(f_k(x, g, d, a) - f_ref(x, g, d, a))))
        _emit_timed(f"kernel/admm_update/pallas-interpret/n={n}", f_k,
                    x, g, d, a, derived=f"max_err={err:.2e}")

    m = 16
    n = 1 << 14 if quick else 1 << 16
    w = jnp.asarray(rng.random((m, m)), jnp.float32)
    w = w / jnp.sum(w, 1, keepdims=True)
    z = jnp.asarray(rng.normal(size=(m, n)), jnp.float32)
    f_ref = jax.jit(lambda w, z: ref.gossip_matmul(w, z))
    _emit_timed(f"kernel/gossip_matmul/jnp/n={n}", f_ref, w, z)
    f_k = jax.jit(lambda w, z: ops.gossip_mix_leaf(w, z))
    err = float(jnp.max(jnp.abs(f_k(w, z) - f_ref(w, z))))
    _emit_timed(f"kernel/gossip_matmul/pallas-interpret/n={n}", f_k, w, z,
                derived=f"max_err={err:.2e}")

    # fused quantized gossip (the int8/int4 wire hot path): the composed
    # quantize -> dequantize -> gate -> mix jnp chain vs one fused Pallas
    # kernel — the chain the non-kernel QuantizeCodec+DenseTransport
    # path runs every round
    r = jnp.asarray(rng.normal(size=(m, n)) * 0.01, jnp.float32)
    u = jnp.asarray(rng.random((m, n)), jnp.float32)
    for bits in (8, 4):
        qmax = float(2 ** (bits - 1) - 1)

        def composed(w, z, r, u, _qmax=qmax, _bits=bits):
            e = z + r
            scale = (jnp.maximum(jnp.max(jnp.abs(e), 1), 1e-12)
                     / _qmax).reshape(-1, 1)
            return ref.gossip_quant(w, z, r, u, scale, bits=_bits)

        f_ref = jax.jit(composed)
        _emit_timed(f"kernel/gossip_quant/jnp-composed/bits={bits}/n={n}",
                    f_ref, w, z, r, u)
        f_k = jax.jit(lambda w, z, r, u, _bits=bits: ops.quantize_mix_leaf(
            w, z, r, u, bits=_bits))
        yk, rk = f_k(w, z, r, u)
        yr, rr = f_ref(w, z, r, u)
        err = max(float(jnp.max(jnp.abs(yk - yr))),
                  float(jnp.max(jnp.abs(rk - rr))))
        _emit_timed(f"kernel/gossip_quant/pallas-fused/bits={bits}/n={n}",
                    f_k, w, z, r, u, derived=f"max_err={err:.2e}")

    if quick:
        return

    # fused selective scan (small shape — interpret mode is a Python loop)
    b, s, d_, n_ = 1, 64, 128, 16
    x = jnp.asarray(rng.normal(size=(b, s, d_)) * 0.5, jnp.float32)
    dt = jnp.asarray(np.abs(rng.normal(size=(b, s, d_))) * 0.1, jnp.float32)
    a_log = jnp.asarray(rng.normal(size=(d_, n_)) * 0.2, jnp.float32)
    bm = jnp.asarray(rng.normal(size=(b, s, n_)) * 0.5, jnp.float32)
    cm = jnp.asarray(rng.normal(size=(b, s, n_)) * 0.5, jnp.float32)
    dsk = jnp.asarray(rng.normal(size=(d_,)), jnp.float32)
    h0 = jnp.zeros((b, d_, n_), jnp.float32)
    f_ref = jax.jit(lambda *a: ref.selective_scan(*a)[0])
    _emit_timed(f"kernel/selective_scan/jnp/s={s}", f_ref,
                x, dt, a_log, bm, cm, dsk, h0)
    f_k = jax.jit(lambda *a: ops.selective_scan(*a)[0])
    err = float(jnp.max(jnp.abs(f_k(x, dt, a_log, bm, cm, dsk, h0)
                                - f_ref(x, dt, a_log, bm, cm, dsk, h0))))
    _emit_timed(f"kernel/selective_scan/pallas-interpret/s={s}", f_k,
                x, dt, a_log, bm, cm, dsk, h0, derived=f"max_err={err:.2e}")
