"""Kernel micro-benchmarks: fused Pallas ops (interpret mode on CPU — a
correctness-speed proxy, not TPU wall time) vs the jnp reference, plus
the arch-scale DFL round step cost on smoke configs."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref

from benchmarks.common import emit, time_fn


def run():
    rng = np.random.default_rng(0)
    for n in (1 << 16, 1 << 20):
        x, g, d, a = (jnp.asarray(rng.normal(size=n), jnp.float32)
                      for _ in range(4))
        f_ref = jax.jit(lambda x, g, d, a: ref.admm_update(
            x, g, d, a, lr=0.1, lam=0.2))
        us = time_fn(f_ref, x, g, d, a)
        emit(f"kernel/admm_update/jnp/n={n}", us, "oracle")
        f_k = jax.jit(lambda x, g, d, a: ops.admm_update(
            x, g, d, a, lr=0.1, lam=0.2))
        us_k = time_fn(f_k, x, g, d, a)
        err = float(jnp.max(jnp.abs(f_k(x, g, d, a) - f_ref(x, g, d, a))))
        emit(f"kernel/admm_update/pallas-interpret/n={n}", us_k,
             f"max_err={err:.2e}")

    m = 16
    w = jnp.asarray(rng.random((m, m)), jnp.float32)
    z = jnp.asarray(rng.normal(size=(m, 1 << 16)), jnp.float32)
    f_ref = jax.jit(lambda w, z: ref.gossip_matmul(w, z))
    emit("kernel/gossip_matmul/jnp/n=65536", time_fn(f_ref, w, z), "oracle")
    f_k = jax.jit(lambda w, z: ops.gossip_mix_leaf(w, z))
    err = float(jnp.max(jnp.abs(f_k(w, z) - f_ref(w, z))))
    emit("kernel/gossip_matmul/pallas-interpret/n=65536",
         time_fn(f_k, w, z), f"max_err={err:.2e}")

    # fused selective scan (small shape — interpret mode is a Python loop)
    b, s, d_, n_ = 1, 64, 128, 16
    x = jnp.asarray(rng.normal(size=(b, s, d_)) * 0.5, jnp.float32)
    dt = jnp.asarray(np.abs(rng.normal(size=(b, s, d_))) * 0.1, jnp.float32)
    a_log = jnp.asarray(rng.normal(size=(d_, n_)) * 0.2, jnp.float32)
    bm = jnp.asarray(rng.normal(size=(b, s, n_)) * 0.5, jnp.float32)
    cm = jnp.asarray(rng.normal(size=(b, s, n_)) * 0.5, jnp.float32)
    dsk = jnp.asarray(rng.normal(size=(d_,)), jnp.float32)
    h0 = jnp.zeros((b, d_, n_), jnp.float32)
    f_ref = jax.jit(lambda *a: ref.selective_scan(*a)[0])
    emit(f"kernel/selective_scan/jnp/s={s}",
         time_fn(f_ref, x, dt, a_log, bm, cm, dsk, h0), "oracle")
    f_k = jax.jit(lambda *a: ops.selective_scan(*a)[0])
    err = float(jnp.max(jnp.abs(f_k(x, dt, a_log, bm, cm, dsk, h0)
                                - f_ref(x, dt, a_log, bm, cm, dsk, h0))))
    emit(f"kernel/selective_scan/pallas-interpret/s={s}",
         time_fn(f_k, x, dt, a_log, bm, cm, dsk, h0), f"max_err={err:.2e}")
