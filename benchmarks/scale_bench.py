"""Cohort virtualization scale-out: million-client populations on one
device, and two-tier hierarchical gossip vs flat dense.

Two claims this suite pins:

* **flat device memory** — with ``n_virtual`` clients virtualized behind
  a fixed hot cohort (``repro.core.cohort``), the per-round us and the
  device-resident state bytes must stay flat while the population grows
  10-1000x (the cold rows live host-side in the ``ClientStore``); the
  ``device_kb`` column is identical across the whole curve by
  construction and the gate catches any accidental O(n_virtual)
  materialization in the round path;
* **hier beats flat dense** — under the cluster-aware ``hub-and-spoke``
  network model (fast LAN inside each cluster + head backbone), the
  two-tier transport's modeled round time (sequential tier critical
  paths, ``NetworkModel.tiered_round_time``) must undercut flat dense
  gossip, which pays the slow cross-cluster spoke links every round.

Rows: ``scale/virtual/n<N>`` (us/round + bytes/round + device_kb as the
population grows), ``scale/hier|dense/m<M>c<C>`` (modeled seconds per
round for both transports over the same cluster network).
"""
import jax
import numpy as np

from benchmarks.common import emit, mlp_init, run_dfl, steady_state_us

COHORT = 16
CLUSTERS = 4


def _device_kb(n_virtual: int) -> float:
    """Device-resident bytes of the hot cohort state (deterministic;
    must not depend on ``n_virtual``)."""
    from repro.core import DFLConfig
    from repro.core.cohort import ClientStore
    cfg = DFLConfig(m=COHORT, topology="ring", n_virtual=n_virtual)
    store = ClientStore(mlp_init(32, 10), cfg, seed=0)
    st = store.gather(np.arange(COHORT))
    leaves = jax.tree.leaves((st.params, st.solver, st.comm, st.rng))
    return sum(leaf.nbytes for leaf in leaves) / 1e3


def run(rounds: int = 16, quick: bool = False):
    populations = (1_000, 10_000) if quick else (1_000, 10_000, 100_000)

    # -- scale-out curve: population grows, device footprint must not --
    for n in populations:
        acc, hist, us = run_dfl("dfedadmm", rounds=rounds, alpha=0.3,
                                m=COHORT, topology="ring",
                                eval_every=rounds, n_virtual=n)
        emit(f"scale/virtual/n{n}", us,
             f"bytes_per_round={hist['wire_bytes'][0]};"
             f"device_kb={_device_kb(n):.1f};"
             f"store_rows={hist['store_touched'][-1]};"
             f"cohort={COHORT};acc={acc:.4f}",
             spread_us=steady_state_us(hist)[1])

    # -- hier vs flat dense under the same cluster-aware network -------
    sims = {}
    for name, kw in (("dense", dict(transport="dense")),
                     ("hier", dict(transport="hier"))):
        acc, hist, us = run_dfl("dfedadmm", rounds=rounds, alpha=0.3,
                                m=COHORT, topology="full",
                                network="hub-and-spoke", clusters=CLUSTERS,
                                eval_every=rounds, **kw)
        sims[name] = float(np.mean(hist["sim_time"]))
        x = "" if name == "dense" else \
            f";xdense={sims['hier'] / sims['dense']:.3f}"
        emit(f"scale/{name}/m{COHORT}c{CLUSTERS}", us,
             f"sim_time_per_round={sims[name]:.4f};acc={acc:.4f}{x}",
             spread_us=steady_state_us(hist)[1])

    # -- async-virtual: event-driven ticks over the virtual population -
    n_async = populations[0]
    acc, hist, us = run_dfl("dfedadmm", rounds=rounds, alpha=0.3, m=COHORT,
                            topology="ring", network="lognormal",
                            execution="async", tick_s=0.5,
                            eval_every=rounds, n_virtual=n_async)
    ticked = float(np.nanmean(hist["ticked"]))
    emit(f"scale/async/n{n_async}", us,
         f"ticked={ticked:.2f};store_rows={hist['store_touched'][-1]};"
         f"cohort={COHORT}", spread_us=steady_state_us(hist)[1])
