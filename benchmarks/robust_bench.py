"""Adversarial robustness: Byzantine attacks vs robust mixing, plus DP.

The threat layer (``repro.core.threat``) lets a seeded fraction of
clients corrupt their *outgoing* gossip messages inside the jitted
round, and lets every honest receiver replace the plain gossip average
with a robust aggregator at the transport level.  This suite measures
what that buys on the paper's synthetic federated task (m=16 clients,
Dirichlet alpha=0.3, random topology, dfedadmm):

* ``robust/clean/mean``       — no attack, plain gossip (control).
* ``robust/signflip20/<agg>`` — 20% of clients sign-flip their message
  every round (``ThreatSpec(attack="signflip", frac=0.2)``); one row per
  registered aggregator (mean / trimmed_mean / median / krum).
* ``robust/dp/<preset>``      — no attack, the ``dp`` wire codec
  (per-client L2 clip + Gaussian noise on the error-feedback residual
  path) at a loose and an aggressive privacy point; the derived column
  carries the mean clipped fraction from ``history["dp_clip_frac"]``.

The headline row, ``robust/headline/signflip20``, pins the acceptance
claim of the subsystem: under 20% sign-flip adversaries, dfedadmm with
``robust="trimmed_mean"`` still reaches the target accuracy while plain
mean mixing does not (the sign-flipped mass survives averaging and the
federation collapses to chance).  ``holds=False`` in that row is a
regression; ``tests/test_threat.py`` pins the same contrast as a slow
test.
"""
from benchmarks.common import emit, rounds_from_history, run_dfl

from repro.core import ThreatSpec, aggregator_names

ATTACK_FRAC = 0.2
ATTACK_SCALE = 1.0

# (label, dp_clip, dp_noise): a loose point where the clip rarely binds
# and an aggressive point where every client clips and the noise bites
DP_PRESETS = (("loose", 10.0, 0.01), ("tight", 1.0, 0.1))


def _rt(hist, target, rounds):
    rt = rounds_from_history(hist, target)
    return rt if rt is not None else f">{rounds}"


def run(rounds: int = 20, m: int = 16, target: float = 0.7):
    common = dict(rounds=rounds, alpha=0.3, m=m, topology="random",
                  eval_every=2)

    acc, hist, us = run_dfl("dfedadmm", **common)
    emit("robust/clean/mean", us,
         f"acc={acc:.4f};rounds_to_{target:g}={_rt(hist, target, rounds)}")

    threat = ThreatSpec(attack="signflip", frac=ATTACK_FRAC,
                        scale=ATTACK_SCALE, seed=0)
    reached = {}
    for agg in sorted(aggregator_names()):
        acc, hist, us = run_dfl("dfedadmm", threat=threat, robust=agg,
                                **common)
        reached[agg] = rounds_from_history(hist, target)
        emit(f"robust/signflip20/{agg}", us,
             f"acc={acc:.4f};"
             f"rounds_to_{target:g}={_rt(hist, target, rounds)};"
             f"adversaries={threat.n_adversaries(m)}/{m}")

    holds = reached["trimmed_mean"] is not None and reached["mean"] is None
    emit("robust/headline/signflip20", 0.0,
         f"holds={holds};"
         f"trimmed_mean_rounds_to_{target:g}="
         f"{reached['trimmed_mean'] or f'>{rounds}'};"
         f"mean_rounds_to_{target:g}={reached['mean'] or f'>{rounds}'}")
    if not holds:
        print("robust_bench: WARNING headline contrast does not hold "
              f"(trimmed_mean={reached['trimmed_mean']}, "
              f"mean={reached['mean']})")

    for label, clip, noise in DP_PRESETS:
        acc, hist, us = run_dfl("dfedadmm", codec="dp", dp_clip=clip,
                                dp_noise=noise, **common)
        cf = [v for v in hist["dp_clip_frac"] if v == v]  # drop NaN
        emit(f"robust/dp/{label}", us,
             f"acc={acc:.4f};"
             f"rounds_to_{target:g}={_rt(hist, target, rounds)};"
             f"clip={clip:g};noise_mult={noise:g};"
             f"clip_frac={sum(cf) / max(len(cf), 1):.2f}")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
