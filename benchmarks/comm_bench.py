"""Communication layer: wire bytes vs rounds-to-target across codecs and
transports.

Two questions the paper's full-precision symmetric setting never asks:

* how much uplink does a codec save, and what does it cost in rounds —
  ``int8`` / 4-bit stochastic rounding and top-k sparsification (all
  with error feedback) against the identity wire;
* what does dropping the symmetry requirement cost — push-sum over a
  one-directional ring vs plain gossip over the symmetric ring.

Each row reports the modeled per-round uplink bytes (sum over active
clients of the codec's message size), the compression factor vs f32,
the final accuracy, and rounds until the eval accuracy first reaches
``target``.  The acceptance bar for the comm redesign: int8 cuts wire
bytes >= 3x without degrading rounds-to-target by more than 20%.
"""
from benchmarks.common import (emit, rounds_from_history, run_cfl, run_dfl,
                               steady_state_us)

CODEC_POINTS = (
    ("identity", dict()),
    ("int8", dict(codec="int8", codec_bits=8)),
    # use_kernel="comm" fuses the wire path only (quantize+EF+mix in one
    # Pallas kernel) without dragging the interpret-mode solver kernels
    # into the round timing
    ("int8-fused", dict(codec="int8", codec_bits=8, use_kernel="comm")),
    ("int4", dict(codec="int8", codec_bits=4)),
    ("int4-fused", dict(codec="int8", codec_bits=4, use_kernel="comm")),
    # fp8 e4m3 wire: same 4x compression as int8 but relative mantissa
    # spacing (no stochastic rounding needed; EF absorbs the RNE bias)
    ("fp8", dict(codec="fp8")),
    ("top32", dict(codec="topk", codec_k=32)),
    ("rand32", dict(codec="randk", codec_k=32)),
)


def run(rounds: int = 20, m: int = 16, algo: str = "dfedadmm",
        target: float = 0.6):
    base_bytes = base_us = None
    for name, kw in CODEC_POINTS:
        acc, hist, us = run_dfl(algo, rounds=rounds, alpha=0.3, m=m,
                                topology="ring", eval_every=2, **kw)
        bpr = hist["wire_bytes"][0]
        if base_bytes is None:
            base_bytes, base_us = bpr, us
        rt = rounds_from_history(hist, target)
        # xus: steady-state us/round relative to the identity wire — the
        # fused int8 acceptance bar (<= 1.3x) reads off this column
        emit(f"comm/codec/{name}", us,
             f"bytes_per_round={bpr};x{base_bytes / bpr:.1f};acc={acc:.4f};"
             f"rounds_to_{target:g}={rt if rt is not None else f'>{rounds}'};"
             f"xus={us / base_us:.2f}",
             spread_us=steady_state_us(hist)[1])

    for name, kw in (
        ("ring", dict(topology="ring")),
        ("dring_pushsum", dict(topology="dring", transport="pushsum")),
        ("dring_pushsum_int4", dict(topology="dring", transport="pushsum",
                                    codec="int8", codec_bits=4)),
        ("drandom_pushsum", dict(topology="drandom", transport="pushsum")),
    ):
        acc, hist, us = run_dfl(algo, rounds=rounds, alpha=0.3, m=m,
                                eval_every=2, **kw)
        rt = rounds_from_history(hist, target)
        emit(f"comm/transport/{name}", us,
             f"bytes_per_round={hist['wire_bytes'][0]};acc={acc:.4f};"
             f"rounds_to_{target:g}={rt if rt is not None else f'>{rounds}'}")

    # centralized baselines through the same history schema: simulate_cfl
    # now records wire bytes (cohort x f32 message) like simulate does, so
    # these rows land in the same table with no renderer special-casing
    for cfl_algo in ("fedavg", "fedpd"):
        acc, hist, us = run_cfl(cfl_algo, rounds=rounds, alpha=0.3, m=m)
        emit(f"comm/cfl/{cfl_algo}", us,
             f"bytes_per_round={hist['wire_bytes'][0]};acc={acc:.4f}")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
