"""End-to-end driver: decentralized federated training of a transformer
LM with DFedADMM-SAM over heterogeneous synthetic token streams.

Presets:
  tiny  (default) — 2L/128d  ~1.9M params, 60 rounds, minutes on CPU.
  100m            — 12L/768d ~100M params; run on a real mesh (the paper's
                    technique is round-identical, only the substrate grows).

    PYTHONPATH=src python examples/train_lm_dfl.py --preset tiny
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import DFLConfig, simulate
from repro.data.synthetic import make_dfl_lm_sampler, make_model_batch
from repro.models import build_model

PRESETS = {
    "tiny": dict(num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
                 d_ff=256, vocab_size=256, rounds=60, m=8, K=2, batch=8,
                 seq=64),
    "100m": dict(num_layers=12, d_model=768, num_heads=12, num_kv_heads=4,
                 d_ff=2048, vocab_size=32000, rounds=300, m=16, K=5,
                 batch=16, seq=512),
}


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--preset", default="tiny", choices=list(PRESETS))
    ap.add_argument("--algorithm", default="dfedadmm_sam")
    ap.add_argument("--rounds", type=int, default=0)
    args = ap.parse_args()
    p = PRESETS[args.preset]
    rounds = args.rounds or p["rounds"]

    cfg = ModelConfig(name=f"lm-{args.preset}", arch_type="dense",
                      num_layers=p["num_layers"], d_model=p["d_model"],
                      num_heads=p["num_heads"], num_kv_heads=p["num_kv_heads"],
                      d_ff=p["d_ff"], vocab_size=p["vocab_size"],
                      rope_theta=1e4, dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    print(f"[lm-dfl] {cfg.name}: {model.param_count(params):,} params, "
          f"m={p['m']} K={p['K']} algo={args.algorithm}")

    dfl = DFLConfig(algorithm=args.algorithm, m=p["m"], K=p["K"], lr=0.05,
                    lam=0.5, rho=0.05, topology="ring")
    sampler = make_dfl_lm_sampler(cfg, p["m"], p["K"], p["batch"], p["seq"])
    eval_batch = jax.tree.map(jnp.asarray,
                              make_model_batch(cfg, p["batch"], p["seq"],
                                               seed=777))

    def eval_fn(pm):
        return {"eval_loss": float(model.loss(pm, eval_batch, None))}

    t0 = time.time()
    state, hist = simulate(model.loss, eval_fn, params, dfl, sampler,
                           rounds=rounds, eval_every=max(rounds // 6, 1),
                           verbose=True)
    print(f"[lm-dfl] {rounds} rounds in {time.time()-t0:.0f}s; "
          f"loss {hist['loss'][0]:.3f} -> {hist['loss'][-1]:.3f}; "
          f"consensus^2 {hist['consensus_sq'][-1]:.5f}")
    assert hist["loss"][-1] < hist["loss"][0], "LM did not learn"


if __name__ == "__main__":
    main()
