"""Quickstart: DFedADMM vs DFedAvg on a heterogeneous federated task.

Runs in ~1 minute on CPU:
    PYTHONPATH=src python examples/quickstart.py

``DFLConfig`` is the single declaration point for all four pluggable
layers (docs/architecture.md): ``algorithm`` resolves through the
solver registry (``repro.core.solvers``), ``transport``/``codec``
select the communication layer (``repro.core.comm``), ``network``
attaches the per-link cost model (``repro.core.network``), and
``participation`` the scenario engine.  The last run below composes
them: 8-bit error-feedback messages, a WAN/LAN network model, and the
modeled wall-clock (``history["sim_time"]``) that int8 buys back.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DFLConfig, mean_params, simulate
from repro.data.synthetic import SyntheticClassification


def mlp_init(dim, n_classes, hidden=48, seed=0):
    r = np.random.default_rng(seed)
    return {"w1": jnp.asarray(r.normal(size=(dim, hidden)) / dim ** 0.5,
                              jnp.float32),
            "b1": jnp.zeros(hidden),
            "w2": jnp.asarray(r.normal(size=(hidden, n_classes)) /
                              hidden ** 0.5, jnp.float32),
            "b2": jnp.zeros(n_classes)}


def logits_fn(p, x):
    return jnp.tanh(x @ p["w1"] + p["b1"]) @ p["w2"] + p["b2"]


def loss_fn(p, batch, rng):
    lg = logits_fn(p, batch["x"])
    return jnp.mean(jax.nn.logsumexp(lg, -1) -
                    jnp.take_along_axis(lg, batch["y"][..., None], -1)[..., 0])


def main():
    m, K, rounds = 16, 5, 20
    task = SyntheticClassification(n_classes=10, dim=24, n_train=8000,
                                   n_test=2000, noise=1.0)
    parts = task.partition(m, alpha=0.1)         # strongly non-IID
    sampler0 = task.client_sampler(parts, batch=32, K=K)

    def sampler(t):
        b = sampler0(t)
        return {"x": jnp.asarray(b["x"]), "y": jnp.asarray(b["y"])}

    def eval_fn(p):
        pred = np.argmax(np.asarray(logits_fn(p, jnp.asarray(task.x_test))),
                         -1)
        return {"acc": float(np.mean(pred == task.y_test))}

    params = mlp_init(task.dim, task.n_classes)
    print(f"== {m} clients, Dirichlet(0.1), ring topology, K={K} ==")
    for algo in ("dfedavg", "dfedadmm", "dfedadmm_sam"):
        cfg = DFLConfig(algorithm=algo, m=m, K=K, topology="ring", lam=1.0)
        state, hist = simulate(loss_fn, eval_fn, params, cfg, sampler,
                               rounds=rounds, eval_every=10)
        acc = eval_fn(mean_params(state.params))["acc"]
        print(f"{algo:14s} final acc={acc:.3f} "
              f"consensus^2={hist['consensus_sq'][-1]:.4f} "
              f"loss={hist['loss'][-1]:.3f}")
    print("\nUnder strong heterogeneity the dual-corrected local steps lift "
          "accuracy and speed up convergence (paper Tables 1 & 3-5).")

    # the layers compose: quantized gossip over a slow WAN/LAN network —
    # same algorithm, ~4x less uplink, and the cost model turns the saved
    # bytes into saved (modeled) wall-clock seconds
    print("\n== dfedadmm + comm/network layers (wan-lan preset) ==")
    for codec in ("identity", "int8"):
        cfg = DFLConfig(algorithm="dfedadmm", m=m, K=K, topology="ring",
                        lam=1.0, codec=codec, network="wan-lan")
        state, hist = simulate(loss_fn, eval_fn, params, cfg, sampler,
                               rounds=rounds, eval_every=10)
        acc = eval_fn(mean_params(state.params))["acc"]
        print(f"codec={codec:9s} final acc={acc:.3f} "
              f"uplink={sum(hist['wire_bytes']) / 1e6:.2f}MB "
              f"sim_time={sum(hist['sim_time']):.2f}s")


if __name__ == "__main__":
    main()
