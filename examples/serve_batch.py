"""Batched serving of a DFL-trained consensus model: train briefly with
DFedADMM, take the client-mean model, then prefill + greedy decode.

    PYTHONPATH=src python examples/serve_batch.py --arch zamba2-1.2b
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_smoke_config
from repro.core import DFLConfig, init_state, make_gossip, make_train_round, \
    mean_params
from repro.data.synthetic import make_model_batch
from repro.models import build_model


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="zamba2-1.2b", choices=list(ARCH_IDS))
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--gen", type=int, default=12)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    # -- brief decentralized training ------------------------------------
    m, K = 4, 2
    dfl = DFLConfig(algorithm="dfedadmm", m=m, K=K, topology="ring", lr=0.02)
    spec = make_gossip("ring", m)
    round_fn = jax.jit(make_train_round(model.loss, dfl, spec=spec))
    state = init_state(params, dfl)
    w = jnp.asarray(spec.matrix, jnp.float32)
    for t in range(args.rounds):
        batch = jax.tree.map(jnp.asarray,
                             make_model_batch(cfg, 2, 32, seed=t,
                                              lead=(m, K)))
        state, metrics = round_fn(state, batch, w)
        print(f"[train] round {t} loss={float(metrics['loss']):.3f}")
    serving_params = mean_params(state.params)

    # -- serve the consensus model ----------------------------------------
    prompt = jax.tree.map(jnp.asarray,
                          make_model_batch(cfg, args.batch, 24, seed=99))
    prompt.pop("labels", None)
    max_seq = 24 + args.gen + 4
    logits, cache = jax.jit(lambda p, b: model.prefill(p, b, max_seq))(
        serving_params, prompt)
    decode = jax.jit(model.decode_step)
    tok = jnp.argmax(logits, -1)
    outs = [np.asarray(tok)]
    t0 = time.time()
    for _ in range(args.gen - 1):
        step_in = (jnp.zeros((args.batch, 1, cfg.d_model), jnp.float32)
                   if cfg.arch_type == "audio" else tok)
        logits, cache = decode(serving_params, cache, step_in)
        tok = jnp.argmax(logits, -1)
        outs.append(np.asarray(tok))
    dt = time.time() - t0
    print(f"[serve] {args.batch} seqs x {args.gen} tokens in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")
    print(f"[serve] seq0: {np.stack(outs, 1)[0].tolist()}")


if __name__ == "__main__":
    main()
