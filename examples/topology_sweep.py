"""Reproduce the paper's topology study (Table 2 / Fig. 2): accuracy of
DFedADMM under Ring / Grid / Exp / Full topologies, with the measured
spectral gap 1-psi for each — then re-run the sweep under partial
participation (half the clients sampled per round, with stragglers) to
show how unreliable clients interact with topology connectivity, and
finally sweep the communication layer itself: push-sum over directed
graphs and compressed (int8 / top-k) gossip messages, reporting the
modeled uplink bytes alongside accuracy.

    PYTHONPATH=src python examples/topology_sweep.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import (DFLConfig, ParticipationSpec, make_gossip,
                        mean_params, simulate)
from repro.data.synthetic import SyntheticClassification

from quickstart import loss_fn, logits_fn, mlp_init


def main():
    m, rounds = 16, 25
    task = SyntheticClassification(n_classes=10, dim=24, n_train=8000,
                                   n_test=2000, noise=1.0)
    parts = task.partition(m, alpha=0.3)
    sampler0 = task.client_sampler(parts, batch=32, K=5)

    def sampler(t):
        b = sampler0(t)
        return {"x": jnp.asarray(b["x"]), "y": jnp.asarray(b["y"])}

    params = mlp_init(task.dim, task.n_classes)
    scenarios = {
        "full": ParticipationSpec(),
        "half+stragglers": ParticipationSpec(mode="fraction", p=0.5,
                                             straggler_frac=0.25,
                                             straggler_steps=2),
    }
    for name, part in scenarios.items():
        print(f"--- participation: {name}")
        print(f"{'topology':10s} {'psi':>8s} {'1-psi':>8s} {'acc':>7s}")
        for topo in ("ring", "grid", "exp", "full"):
            spec = make_gossip(topo, m)
            cfg = DFLConfig(algorithm="dfedadmm", m=m, K=5, topology=topo,
                            lam=0.2, participation=part)
            state, _ = simulate(loss_fn, None, params, cfg, sampler,
                                rounds=rounds)
            pred = np.argmax(np.asarray(
                logits_fn(mean_params(state.params),
                          jnp.asarray(task.x_test))), -1)
            acc = float(np.mean(pred == task.y_test))
            print(f"{topo:10s} {spec.psi:8.4f} {spec.spectral_gap:8.4f} "
                  f"{acc:7.3f}")
        print()
    print("Better-connected topologies (larger spectral gap) converge to "
          "higher accuracy — Corollary 1; partial participation thins every "
          "topology toward ring-like mixing.")

    print("--- communication layer: transports x codecs")
    print(f"{'scenario':26s} {'acc':>7s} {'uplink/round':>13s}")
    for name, kw in (
        ("ring / identity", dict(topology="ring")),
        ("dring / push-sum", dict(topology="dring", transport="pushsum")),
        ("dring / push-sum + int8", dict(topology="dring",
                                         transport="pushsum", codec="int8")),
        ("ring / int4", dict(topology="ring", codec="int8", codec_bits=4)),
        ("ring / top-64", dict(topology="ring", codec="topk", codec_k=64)),
    ):
        cfg = DFLConfig(algorithm="dfedadmm", m=m, K=5, lam=0.2, **kw)
        state, hist = simulate(loss_fn, None, params, cfg, sampler,
                               rounds=rounds)
        pred = np.argmax(np.asarray(
            logits_fn(mean_params(state.params), jnp.asarray(task.x_test))),
            -1)
        acc = float(np.mean(pred == task.y_test))
        print(f"{name:26s} {acc:7.3f} {hist['wire_bytes'][0]/1e3:10.1f} kB")
    print("Push-sum keeps directed (one-directional) rings competitive with "
          "symmetric gossip, and error-feedback compression cuts uplink "
          "bytes ~4-8x at matching accuracy.")


if __name__ == "__main__":
    main()
