"""Pallas TPU kernels for the paper's elementwise hot spots.

Each kernel module pairs with ``ref.py`` (pure-jnp oracle) and is
validated in interpret mode on CPU; ``ops.py`` holds the jit'd public
wrappers used by ``core/admm.py`` / ``core/sam.py`` behind
``DFLConfig.use_kernel``.
"""
from repro.kernels import ops, ref
