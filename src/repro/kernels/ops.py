"""jit'd public wrappers around the Pallas kernels.

Handle arbitrary leaf shapes by flattening + padding to (rows, 128),
dispatch to the kernel (interpret=True on CPU — the container has no TPU;
on TPU backends interpret is switched off automatically), and restore the
original shape.  Scalars (lr, 1/lam, SAM scale) ride in as (1, k) f32
arrays so they may be traced values.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import admm_update as _admm
from repro.kernels import gossip_matmul as _gossip
from repro.kernels import gossip_quant as _gq
from repro.kernels import quantize as _quant
from repro.kernels import sam_scale as _sam
from repro.kernels import selective_scan as _sscan

LANE = 128
SUBLANE = 8


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _to_planes(x, row_tile):
    """Flatten to (R, 128) with R a multiple of row_tile; returns
    (planes, orig_size)."""
    flat = x.reshape(-1)
    n = flat.shape[0]
    per_tile = row_tile * LANE
    padded = ((n + per_tile - 1) // per_tile) * per_tile
    if padded != n:
        flat = jnp.pad(flat, (0, padded - n))
    return flat.reshape(-1, LANE), n


def _from_planes(planes, n, shape, dtype):
    return planes.reshape(-1)[:n].reshape(shape).astype(dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(6,))
def _admm_core(x, g, d, a, lr, lam, interpret):
    row_tile = _admm.ROW_TILE
    xp, n = _to_planes(x, row_tile)
    gp, _ = _to_planes(g.astype(x.dtype), row_tile)
    dp, _ = _to_planes(d.astype(x.dtype), row_tile)
    ap, _ = _to_planes(a.astype(x.dtype), row_tile)
    scalars = jnp.stack([jnp.asarray(lr, jnp.float32),
                         1.0 / jnp.asarray(lam, jnp.float32)]).reshape(1, 2)
    yp = _admm.admm_update_2d(xp, gp, dp, ap, scalars, interpret=interpret)
    return _from_planes(yp, n, x.shape, x.dtype)


def _admm_fwd(x, g, d, a, lr, lam, interpret):
    return _admm_core(x, g, d, a, lr, lam, interpret), (x, g, d, a, lr, lam)


def _admm_bwd(interpret, res, ct):
    # y = x - lr*(g - d + (x - a)/lam): linear in every operand.
    x, g, d, a, lr, lam = res
    ctf = ct.astype(jnp.float32)
    lr = jnp.asarray(lr, jnp.float32)
    lam = jnp.asarray(lam, jnp.float32)
    upd = (g - d).astype(jnp.float32) + (x - a).astype(jnp.float32) / lam
    dx = (ctf * (1.0 - lr / lam)).astype(x.dtype)
    dg = (-ctf * lr).astype(g.dtype)
    dd = (ctf * lr).astype(d.dtype)
    da = (ctf * lr / lam).astype(a.dtype)
    dlr = -jnp.sum(ctf * upd)
    dlam = jnp.sum(ctf * lr * (x - a).astype(jnp.float32)) / (lam * lam)
    return dx, dg, dd, da, dlr, dlam


_admm_core.defvjp(_admm_fwd, _admm_bwd)


def admm_update(x, g, d, a, *, lr, lam, interpret: bool | None = None):
    """Fused Eq. 6 update for ONE leaf; same shape/dtype as x.
    Differentiable (custom VJP; the op is linear in all operands)."""
    interpret = _interpret_default() if interpret is None else interpret
    return _admm_core(x, g, d, a, jnp.asarray(lr, jnp.float32),
                      jnp.asarray(lam, jnp.float32), interpret)


def global_sumsq(tree, *, interpret: bool | None = None):
    """Sum of squares over a whole pytree via the block-reduce kernel."""
    interpret = _interpret_default() if interpret is None else interpret
    total = jnp.zeros((), jnp.float32)
    for leaf in jax.tree.leaves(tree):
        planes, n = _to_planes(leaf, _sam.ROW_TILE)
        partials = _sam.block_sumsq_2d(planes, interpret=interpret)
        total = total + jnp.sum(partials)
        # padding contributes zeros; nothing to subtract
    return total


def sam_scale(x, g, scale, *, interpret: bool | None = None):
    """y = x + scale * g for one leaf (scale traced scalar)."""
    interpret = _interpret_default() if interpret is None else interpret
    xp, n = _to_planes(x, _sam.ROW_TILE)
    gp, _ = _to_planes(g.astype(x.dtype), _sam.ROW_TILE)
    s = jnp.asarray(scale, jnp.float32).reshape(1, 1)
    yp = _sam.scale_add_2d(xp, gp, s, interpret=interpret)
    return _from_planes(yp, n, x.shape, x.dtype)


def sgd_update(x, g, *, lr, interpret: bool | None = None):
    """Fused y = x - lr*g for one leaf: the SGD-family solvers' inner
    update routed through the scale-add kernel (scale = -lr, traced)."""
    return sam_scale(x, g, -jnp.asarray(lr, jnp.float32),
                     interpret=interpret)


def gossip_mix_leaf(w, z, *, interpret: bool | None = None):
    """z: (m, ...) one stacked leaf; returns W @ z over the client axis."""
    interpret = _interpret_default() if interpret is None else interpret
    m = z.shape[0]
    flat = z.reshape(m, -1)
    n = flat.shape[1]
    pad = (-n) % _gossip.COL_TILE
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
    out = _gossip.gossip_matmul_2d(jnp.asarray(w, jnp.float32), flat,
                                   interpret=interpret)
    return out[:, :n].reshape(z.shape).astype(z.dtype)


def gossip_mix(w, tree, *, interpret: bool | None = None):
    return jax.tree.map(
        functools.partial(gossip_mix_leaf, w, interpret=interpret), tree)


def _pad_client_planes(x, col_tile):
    """Stacked (m, ...) leaf -> padded (m', N') 2-D planes for the
    quantize kernels, with m' a sublane multiple and N' a lane/tile
    multiple.  Returns (planes, m, n)."""
    m = x.shape[0]
    flat = x.reshape(m, -1)
    n = flat.shape[1]
    pad_m = (-m) % SUBLANE
    pad_n = (-n) % col_tile
    if pad_m or pad_n:
        flat = jnp.pad(flat, ((0, pad_m), (0, pad_n)))
    return flat, m, n


def quantize_leaf(x, u, *, bits: int = 8, interpret: bool | None = None):
    """Fused stochastic quantize + error-feedback residual for ONE stacked
    (m, ...) leaf.

    ``u`` is a uniform-[0,1) array shaped like ``x`` (the caller owns the
    PRNG so kernel and oracle see identical bits).  Returns
    ``(q int8 (m, ...), scale (m,) f32, residual (m, ...) x.dtype)`` with
    a per-client symmetric scale ``max|x_i| / qmax`` (floored away from
    zero so an all-zero message quantizes to exact zeros).
    """
    interpret = _interpret_default() if interpret is None else interpret
    qmax = float(2 ** (bits - 1) - 1)
    m = x.shape[0]
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)).reshape(m, -1), axis=1)
    scale = jnp.maximum(absmax, jnp.float32(1e-12)) / qmax
    xp, _, n = _pad_client_planes(x, _quant.COL_TILE)
    up, _, _ = _pad_client_planes(u.astype(jnp.float32), _quant.COL_TILE)
    # padded rows divide by 1.0, not 0.0 (their outputs are discarded)
    sp = jnp.pad(scale, (0, xp.shape[0] - m), constant_values=1.0)
    q, r = _quant.quantize_2d(xp, sp.reshape(-1, 1), up, bits=bits,
                              interpret=interpret)
    return (q[:m, :n].reshape(x.shape), scale,
            r[:m, :n].reshape(x.shape).astype(x.dtype))


def quantize_mix_leaf(w, z, r, u, active=None, *, bits: int = 8,
                      interpret: bool | None = None):
    """Fused quantized gossip for ONE stacked (m, ...) leaf: quantize the
    error-compensated message ``e = z + r``, mix the dequantized
    estimates with ``W``, and carry the error-feedback residual — one
    kernel, no materialized f32 message copies (``kernels/gossip_quant``).

    ``u`` is a uniform-[0,1) array shaped like ``z`` (caller owns the
    PRNG, so the fused path and the composed oracle see identical bits);
    ``active`` an optional (m,) bool mask — inactive clients mix their
    raw self-message and keep their residual.  Returns
    ``(x (m, ...) z.dtype, resid' (m, ...) r.dtype)``.
    """
    interpret = _interpret_default() if interpret is None else interpret
    qmax = float(2 ** (bits - 1) - 1)
    m = z.shape[0]
    e = z.astype(jnp.float32) + r.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(e).reshape(m, -1), axis=1)
    scale = jnp.maximum(absmax, jnp.float32(1e-12)) / qmax
    # single grid step for small leaves (typical model layers): grid
    # overhead, not FLOPs, dominates them — one 4 KiB-lane tile still
    # fits VMEM comfortably at m <= 32
    nflat = z.size // z.shape[0]
    tile = _gq.COL_TILE if nflat > 4096 else max(LANE, -(-nflat // LANE) * LANE)
    zp, _, n = _pad_client_planes(z, tile)
    rp, _, _ = _pad_client_planes(r.astype(jnp.float32), tile)
    up, _, _ = _pad_client_planes(u.astype(jnp.float32), tile)
    mp = zp.shape[0]
    # padded rows divide by 1.0 and quantize zeros (outputs discarded)
    sp = jnp.pad(scale, (0, mp - m), constant_values=1.0)
    act = jnp.ones((m,), jnp.float32) if active is None else \
        active.astype(jnp.float32)
    ap = jnp.pad(act, (0, mp - m), constant_values=1.0)
    wp = jnp.pad(jnp.asarray(w, jnp.float32),
                 ((0, mp - m), (0, mp - m)))
    y, rout = _gq.gossip_quant_2d(wp, zp, rp, up, sp.reshape(-1, 1),
                                  ap.reshape(-1, 1), bits=bits,
                                  interpret=interpret, col_tile=tile)
    return (y[:m, :n].reshape(z.shape).astype(z.dtype),
            rout[:m, :n].reshape(z.shape).astype(r.dtype))


def dequantize_leaf(q, scale, shape, dtype, *, interpret: bool | None = None):
    """Inverse wire map for one leaf: int8 values + (m,) scale -> (m, ...)."""
    interpret = _interpret_default() if interpret is None else interpret
    m = q.shape[0]
    qp, _, n = _pad_client_planes(q, _quant.COL_TILE)
    sp = jnp.pad(scale, (0, qp.shape[0] - m), constant_values=1.0)
    y = _quant.dequantize_2d(qp, sp.reshape(-1, 1), out_dtype=dtype,
                             interpret=interpret)
    return y[:m, :n].reshape(shape)


def selective_scan(x, dt, a_log, b, c, dskip, h0=None, *,
                   interpret: bool | None = None):
    """Fused Mamba-1 selective scan (forward / serving path).

    x/dt (B,S,D); a_log (D,N); b/c (B,S,N); dskip (D,);
    h0 (B,D,N) f32 or None.  Pads D to the channel tile and S to the
    sequence chunk, dispatches the Pallas kernel, and un-pads.
    """
    interpret = _interpret_default() if interpret is None else interpret
    B, S, D = x.shape
    N = a_log.shape[1]
    tile_d = min(_sscan.TILE_D, D) if D % _sscan.TILE_D else _sscan.TILE_D
    pad_d = (-D) % tile_d
    chunk = min(_sscan.CHUNK_S, S)
    pad_s = (-S) % chunk
    if h0 is None:
        h0 = jnp.zeros((B, D, N), jnp.float32)

    if pad_d or pad_s:
        pd, ps = (0, pad_d), (0, pad_s)
        x = jnp.pad(x, ((0, 0), ps, pd))
        dt = jnp.pad(dt, ((0, 0), ps, pd))
        a_log = jnp.pad(a_log, (pd, (0, 0)))
        b = jnp.pad(b, ((0, 0), ps, (0, 0)))
        c = jnp.pad(c, ((0, 0), ps, (0, 0)))
        dskip = jnp.pad(dskip, pd)
        h0 = jnp.pad(h0, ((0, 0), pd, (0, 0)))

    y, h_last = _sscan.selective_scan_3d(x, dt, a_log, b, c, dskip, h0,
                                         interpret=interpret, tile_d=tile_d,
                                         seq_chunk=chunk)
    return y[:, :S, :D], h_last[:, :D, :]
