"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax.numpy as jnp


def admm_update(x, g, d, a, *, lr, lam):
    return x - lr * (g - d + (x - a) / lam)


def sumsq(x):
    return jnp.sum(jnp.square(x.astype(jnp.float32)))


def scale_add(x, g, scale):
    return x + (scale * g.astype(jnp.float32)).astype(x.dtype)


def quantize_stochastic(x, scale, u, *, bits=8):
    """Oracle for ``quantize.quantize_2d``: x/u (m, N); scale (m, 1) f32.
    Returns (q int8, residual x.dtype)."""
    qmax = float(2 ** (bits - 1) - 1)
    xf = x.astype(jnp.float32)
    sf = scale.astype(jnp.float32)
    q = jnp.clip(jnp.floor(xf / sf + u.astype(jnp.float32)), -qmax, qmax)
    return q.astype(jnp.int8), (xf - q * sf).astype(x.dtype)


def dequantize(q, scale, *, out_dtype=jnp.float32):
    """Oracle for ``quantize.dequantize_2d``."""
    return (q.astype(jnp.float32) * scale.astype(jnp.float32)).astype(out_dtype)


def gossip_matmul(w, z):
    return jnp.einsum("ij,jn->in", w.astype(jnp.float32),
                      z.astype(jnp.float32)).astype(z.dtype)


def gossip_quant(w, z, resid, u, scale, active=None, *, bits=8):
    """Oracle for ``gossip_quant.gossip_quant_2d`` — the composed chain
    quantize -> dequantize -> gate -> mix.

    w (m, m) f32; z/resid/u (m, N); scale (m, 1) f32; active (m,) bool
    or None (all active).  Returns ``(x z.dtype, resid' resid.dtype)``.
    """
    q, rr = quantize_stochastic(z.astype(jnp.float32) + resid.astype(
        jnp.float32), scale, u, bits=bits)
    zhat = dequantize(q, scale)
    if active is not None:
        gate = active.reshape(-1, 1)
        zhat = jnp.where(gate, zhat, z.astype(jnp.float32))
        rr = jnp.where(gate, rr, resid)
    return gossip_matmul(w, zhat).astype(z.dtype), rr.astype(resid.dtype)


def selective_scan(x, dt, a_log, b, c, dskip, h0):
    """Mamba-1 recurrence oracle via lax.scan over time.

    x/dt (B,S,D); a_log (D,N); b/c (B,S,N); dskip (D,); h0 (B,D,N) f32.
    Returns (y (B,S,D) x.dtype, h_last (B,D,N) f32).
    """
    import jax

    a_neg = -jnp.exp(a_log.astype(jnp.float32))             # (D,N)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    cf = c.astype(jnp.float32)

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp                           # (B,D)/(B,N)
        a_t = jnp.exp(dt_t[..., None] * a_neg[None])        # (B,D,N)
        h = a_t * h + (dt_t * x_t)[..., None] * b_t[:, None, :]
        y_t = jnp.einsum("bdn,bn->bd", h, c_t) + dskip.astype(jnp.float32) * x_t
        return h, y_t

    xs = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
          jnp.moveaxis(bf, 1, 0), jnp.moveaxis(cf, 1, 0))
    h_last, ys = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), h_last
