"""Fused quantize/dequantize kernels for compressed gossip messages.

The wire-compression hot loop of ``repro.core.comm.QuantizeCodec``:
per-client stochastic rounding of the (m, N) flattened message against a
per-client scale, fused with the error-feedback residual computation —
one read of the f32 message produces both the int8 wire values and the
residual that feeds the next round, instead of three separate
elementwise passes (quantize, dequantize, subtract).

Layout mirrors ``gossip_matmul``: the client axis m is tiny (padded to
the sublane multiple by the ops wrapper) and the flattened parameter
axis N streams through in column tiles, with the (m, 1) scale resident
for the whole grid.  Randomness rides in as a precomputed uniform plane
so the kernel stays deterministic, differentiable-free elementwise math
that is exact in interpret mode on CPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

COL_TILE = 512


def _quant_kernel(x_ref, scale_ref, u_ref, q_ref, r_ref, *, qmax):
    x = x_ref[...].astype(jnp.float32)
    s = scale_ref[...].astype(jnp.float32)          # (m, 1), broadcasts
    # stochastic rounding: E[floor(y + u)] = y for u ~ U[0, 1)
    q = jnp.floor(x / s + u_ref[...])
    q = jnp.clip(q, -qmax, qmax)
    q_ref[...] = q.astype(jnp.int8)
    r_ref[...] = (x - q * s).astype(r_ref.dtype)


def quantize_2d(x, scale, u, *, bits: int = 8, interpret: bool = True,
                col_tile: int = COL_TILE):
    """x: (m, N); scale: (m, 1) f32 (> 0); u: (m, N) f32 uniform [0, 1).

    Returns ``(q int8, residual x.dtype)`` with
    ``q = clip(floor(x/scale + u), -qmax, qmax)`` and
    ``residual = x - q * scale`` (the error-feedback carry).
    """
    m, n = x.shape
    qmax = float(2 ** (bits - 1) - 1)
    grid = (pl.cdiv(n, col_tile),)
    spec = pl.BlockSpec((m, col_tile), lambda j: (0, j))
    return pl.pallas_call(
        functools.partial(_quant_kernel, qmax=qmax),
        grid=grid,
        in_specs=[spec, pl.BlockSpec((m, 1), lambda j: (0, 0)), spec],
        out_specs=[spec, spec],
        out_shape=[jax.ShapeDtypeStruct(x.shape, jnp.int8),
                   jax.ShapeDtypeStruct(x.shape, x.dtype)],
        interpret=interpret,
    )(x, scale, u)


def _dequant_kernel(q_ref, scale_ref, y_ref):
    s = scale_ref[...].astype(jnp.float32)
    y_ref[...] = (q_ref[...].astype(jnp.float32) * s).astype(y_ref.dtype)


def dequantize_2d(q, scale, *, out_dtype=jnp.float32, interpret: bool = True,
                  col_tile: int = COL_TILE):
    """q: (m, N) int8; scale: (m, 1) f32 -> (m, N) ``out_dtype``."""
    m, n = q.shape
    grid = (pl.cdiv(n, col_tile),)
    spec = pl.BlockSpec((m, col_tile), lambda j: (0, j))
    return pl.pallas_call(
        _dequant_kernel,
        grid=grid,
        in_specs=[spec, pl.BlockSpec((m, 1), lambda j: (0, 0))],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, out_dtype),
        interpret=interpret,
    )(q, scale)
