"""SAM perturbation kernels (Alg. 1 lines 10-11).

Two phases over flattened (rows, 128) parameter planes:
  1. ``block_sumsq``  — per-tile partial sum of squares (f32 accumulate),
     reduced on-host to the client-global ||g||^2.
  2. ``scale_add``    — y = x + scale * g with the broadcast scalar
     scale = rho / (||g|| + eps).

Tiles are (512, 128): a single f32 input buffer is 256 KiB; the partial
output is one f32 per tile (SMEM-sized).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
ROW_TILE = 512


def _sumsq_kernel(x_ref, out_ref):
    x = x_ref[...].astype(jnp.float32)
    out_ref[0, 0] = jnp.sum(x * x)


def block_sumsq_2d(x, *, interpret: bool = True, row_tile: int = ROW_TILE):
    """x: (R, 128) -> (num_tiles, 1) f32 partial sums of squares."""
    rows = x.shape[0]
    grid = (pl.cdiv(rows, row_tile),)
    return pl.pallas_call(
        _sumsq_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((row_tile, LANE), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((grid[0], 1), jnp.float32),
        interpret=interpret,
    )(x)


def _scale_kernel(scale_ref, x_ref, g_ref, y_ref):
    scale = scale_ref[0, 0]
    y_ref[...] = x_ref[...] + (scale * g_ref[...].astype(jnp.float32)
                               ).astype(x_ref.dtype)


def scale_add_2d(x, g, scale, *, interpret: bool = True,
                 row_tile: int = ROW_TILE):
    """y = x + scale * g.  x/g: (R,128); scale: (1,1) f32."""
    rows = x.shape[0]
    grid = (pl.cdiv(rows, row_tile),)
    spec = pl.BlockSpec((row_tile, LANE), lambda i: (i, 0))
    return pl.pallas_call(
        _scale_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1, 1), lambda i: (0, 0)), spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(scale, x, g)
