"""Gossip mixing matmul (Alg. 1 line 19): X' = W @ Z with W (m, m) tiny
and Z (m, N) the flattened client-stacked parameters.

The contraction dimension (m <= 32) is far below the 128x128 MXU tile, so
the useful blocking is over the huge N axis: W stays resident in VMEM for
the whole grid while Z streams through in (m, 512) column tiles — one
HBM read of Z and one write of X' total, W read once.

W is padded to (8k, 8k) sublane multiples by the ops wrapper; f32
accumulate regardless of the Z dtype.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

COL_TILE = 512


def _kernel(w_ref, z_ref, y_ref):
    w = w_ref[...].astype(jnp.float32)
    z = z_ref[...].astype(jnp.float32)
    y_ref[...] = jnp.dot(w, z, preferred_element_type=jnp.float32).astype(
        y_ref.dtype)


def gossip_matmul_2d(w, z, *, interpret: bool = True,
                     col_tile: int = COL_TILE):
    """w: (m, m) f32; z: (m, N) -> (m, N), N a multiple of 128."""
    m, n = z.shape
    grid = (pl.cdiv(n, col_tile),)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((m, m), lambda j: (0, 0)),
                  pl.BlockSpec((m, col_tile), lambda j: (0, j))],
        out_specs=pl.BlockSpec((m, col_tile), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct(z.shape, z.dtype),
        interpret=interpret,
    )(w, z)
