"""Fused quantized-gossip kernel: dequantize -> mix -> requantize residual.

One round of compressed gossip (``QuantizeCodec`` + ``DenseTransport``)
is, per leaf::

    e    = z + resid                       error-compensated message
    q    = clip(floor(e / s + u), +-qmax)  stochastic rounding (wire)
    zhat = q * s                           what receivers reconstruct
    r'   = e - zhat                        error-feedback carry
    x    = W @ sel(zhat, z)                gossip contraction
                                           (sel: inactive clients gossip
                                           their raw self-message)

Composed from ``quantize.py`` + ``gossip_matmul.py`` this round-trips a
full f32 copy of every client's message through HBM three times (encode
writes q and r, decode writes zhat, the matmul reads zhat).  This kernel
fuses the whole chain over the same column-tile loop as
``gossip_matmul``: W, the per-client scale, and the participation gate
stay resident in VMEM for the whole grid while z/resid/u stream through
in (m, 512) tiles — each tile is quantized, dequantized, gated, and
contracted in registers, and only the mixed output x and the new
residual r' are ever written back.  The int8 wire tensor is never
materialized (the simulation models its bytes; nothing consumes its
value once x and r' exist).

The per-client scale ``s = max|e| / qmax`` is a full-row reduction, so
it is computed by the ops wrapper in a first pass (exactly like
``quantize_leaf``); randomness rides in as a precomputed uniform plane
so kernel and oracle see identical bits.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

COL_TILE = 512


def _kernel(w_ref, z_ref, r_ref, u_ref, scale_ref, act_ref, y_ref, rout_ref,
            *, qmax):
    z = z_ref[...].astype(jnp.float32)
    e = z + r_ref[...].astype(jnp.float32)
    s = scale_ref[...].astype(jnp.float32)          # (m, 1), broadcasts
    q = jnp.clip(jnp.floor(e / s + u_ref[...]), -qmax, qmax)
    zhat = q * s
    a = act_ref[...].astype(jnp.float32)            # (m, 1) gate in {0, 1}
    # inactive clients transmit nothing: their raw message mixes (the
    # identity row of the masked W holds them in place) and their
    # residual passes through untouched
    zsel = a * zhat + (1.0 - a) * z
    rout_ref[...] = (a * (e - zhat)
                     + (1.0 - a) * r_ref[...].astype(jnp.float32)
                     ).astype(rout_ref.dtype)
    w = w_ref[...].astype(jnp.float32)
    y_ref[...] = jnp.dot(w, zsel,
                         preferred_element_type=jnp.float32).astype(y_ref.dtype)


def gossip_quant_2d(w, z, resid, u, scale, active, *, bits: int = 8,
                    interpret: bool = True, col_tile: int = COL_TILE):
    """w: (m, m) f32; z/resid/u: (m, N); scale/active: (m, 1) f32.

    Returns ``(x, resid')`` — the mixed parameters ``W @ sel(zhat, z)``
    in ``z.dtype`` and the new error-feedback residual in ``resid.dtype``
    — without materializing zhat or the int8 wire tensor.
    """
    m, n = z.shape
    qmax = float(2 ** (bits - 1) - 1)
    grid = (pl.cdiv(n, col_tile),)
    spec = pl.BlockSpec((m, col_tile), lambda j: (0, j))
    col = pl.BlockSpec((m, 1), lambda j: (0, 0))
    return pl.pallas_call(
        functools.partial(_kernel, qmax=qmax),
        grid=grid,
        in_specs=[pl.BlockSpec((m, m), lambda j: (0, 0)),
                  spec, spec, spec, col, col],
        out_specs=[spec, spec],
        out_shape=[jax.ShapeDtypeStruct(z.shape, z.dtype),
                   jax.ShapeDtypeStruct(z.shape, resid.dtype)],
        interpret=interpret,
    )(w, z, resid, u, scale, active)
