"""Fused DFedADMM inner update (Alg. 1 line 13 / Eq. 6) as a Pallas TPU
kernel:

    y = x - lr * (g - d + (x - a) / lam)

The naive jnp version reads x twice and materialises two temporaries;
the fused kernel streams (x, g, d, a) through VMEM once per tile and
writes y — 4 reads + 1 write of HBM traffic, the roofline floor for this
elementwise op.  The K-step local loop runs this over every parameter
element m*K times per round, which makes it the paper-specific hot spot.

Layout: parameters are flattened and padded to (rows, 128) with row-tiles
of 256 — (256, 128) f32 = 128 KiB per operand buffer, 5 buffers = 640 KiB,
comfortably inside the ~16 MiB v5e VMEM while giving the VPU long
contiguous lanes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
ROW_TILE = 256


def _kernel(scalars_ref, x_ref, g_ref, d_ref, a_ref, y_ref):
    lr = scalars_ref[0, 0]
    inv_lam = scalars_ref[0, 1]
    x = x_ref[...].astype(jnp.float32)
    upd = (g_ref[...].astype(jnp.float32) - d_ref[...].astype(jnp.float32)
           + (x - a_ref[...].astype(jnp.float32)) * inv_lam)
    y_ref[...] = (x - lr * upd).astype(y_ref.dtype)


def admm_update_2d(x, g, d, a, scalars, *, interpret: bool = True,
                   row_tile: int = ROW_TILE):
    """x/g/d/a: (R, 128) same dtype; scalars: (1, 2) f32 [lr, 1/lam]."""
    rows = x.shape[0]
    grid = (pl.cdiv(rows, row_tile),)
    tile = (row_tile, LANE)
    spec = pl.BlockSpec(tile, lambda i: (i, 0))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1, 2), lambda i: (0, 0)), spec, spec, spec,
                  spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(scalars, x, g, d, a)
