"""Fused Mamba-1 selective scan as a Pallas TPU kernel.

The recurrence per (channel d, state n):

    a_t = exp(dt_t[d] * A[d, n])
    h_t = a_t * h_{t-1} + (dt_t[d] * x_t[d]) * B_t[n]
    y_t[d] = sum_n h_t[d, n] * C_t[n]  +  D[d] * x_t[d]

The naive jnp formulation materializes a/b/h at (B, S, D, N) f32 in HBM
— the §Roofline-measured memory hog of SSM training/prefill (the CUDA
fused selective-scan exists for exactly this reason).  TPU adaptation:

  * grid over (batch, channel-tiles); TIME LOOPS INSIDE the kernel with
    the running state h (tile_d, N) resident in VMEM for the whole
    sequence — h never touches HBM except the final value;
  * HBM traffic is the roofline floor: read x/dt (S, tile_d), B/C
    (S, N), A (tile_d, N) once; write y (S, tile_d) once;
  * the (tile_d, N) update is a VPU-shaped elementwise block; the
    y-reduction over N is a tiny contraction done as a broadcast
    multiply + lane reduction (N = 16 for falcon-mamba — far below MXU
    size, so the VPU path is the right one).

VMEM budget at defaults (tile_d=128, S-chunked streaming of x/dt/y in
(CHUNK_S, tile_d) blocks, N=16):
  x/dt/y chunks 3 x (512, 128) f32 = 768 KiB, B/C (512, 16-pad-128) f32,
  A/h (128, 128-pad) f32 — ~2 MiB, comfortably inside ~16 MiB v5e VMEM.

Forward/inference kernel (prefill + scoring).  Training keeps the
`chunked_ssm` jnp form (XLA handles its backward); the kernel carries no
custom VJP by design — it is the serving-path hot-spot fix.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_D = 128
CHUNK_S = 512


def _kernel(x_ref, dt_ref, a_log_ref, b_ref, c_ref, dskip_ref, h0_ref,
            y_ref, hout_ref, *, seq_chunk: int):
    """One (batch b, channel-tile i) grid cell; loops time inside.

    Block shapes (leading batch block of 1 squeezed by indexing):
      x_ref/dt_ref/y_ref : (1, S, tile_d)
      b_ref/c_ref        : (1, S, N)
      a_log_ref          : (tile_d, N)
      dskip_ref          : (1, tile_d)
      h0_ref/hout_ref    : (1, tile_d, N)
    """
    s_total = x_ref.shape[1]
    a_neg = -jnp.exp(a_log_ref[...].astype(jnp.float32))   # (tile_d, N)
    dskip = dskip_ref[0, :].astype(jnp.float32)            # (tile_d,)

    def chunk_body(ci, h):
        start = ci * seq_chunk
        xc = x_ref[0, pl.dslice(start, seq_chunk), :].astype(jnp.float32)
        dtc = dt_ref[0, pl.dslice(start, seq_chunk), :].astype(jnp.float32)
        bc = b_ref[0, pl.dslice(start, seq_chunk), :].astype(jnp.float32)
        cc = c_ref[0, pl.dslice(start, seq_chunk), :].astype(jnp.float32)

        def step(t, carry):
            h_, yc = carry
            a_t = jnp.exp(dtc[t][:, None] * a_neg)          # (tile_d, N)
            bx = (dtc[t] * xc[t])[:, None] * bc[t][None, :]  # (tile_d, N)
            h_ = a_t * h_ + bx
            y_t = jnp.sum(h_ * cc[t][None, :], axis=1) + dskip * xc[t]
            yc = jax.lax.dynamic_update_index_in_dim(yc, y_t, t, 0)
            return h_, yc

        yc0 = jnp.zeros((seq_chunk, xc.shape[1]), jnp.float32)
        h, yc = jax.lax.fori_loop(0, seq_chunk, step, (h, yc0))
        y_ref[0, pl.dslice(start, seq_chunk), :] = yc.astype(y_ref.dtype)
        return h

    h = h0_ref[0, ...].astype(jnp.float32)
    n_chunks = s_total // seq_chunk
    h = jax.lax.fori_loop(0, n_chunks, chunk_body, h)
    hout_ref[0, ...] = h.astype(hout_ref.dtype)


def selective_scan_3d(x, dt, a_log, b, c, dskip, h0, *,
                      interpret: bool = True, tile_d: int = TILE_D,
                      seq_chunk: int = CHUNK_S):
    """x/dt: (B, S, D); a_log: (D, N); b/c: (B, S, N); dskip: (D,);
    h0: (B, D, N) f32.  Returns (y (B, S, D) x.dtype, h_last (B, D, N) f32).

    Requires D % tile_d == 0 and S % seq_chunk == 0 (ops wrapper pads).
    """
    B, S, D = x.shape
    N = a_log.shape[1]
    grid = (B, D // tile_d)

    kern = functools.partial(_kernel, seq_chunk=min(seq_chunk, S))
    if S % min(seq_chunk, S):
        raise ValueError(f"S={S} must be a multiple of seq_chunk={seq_chunk}")

    sd_spec = pl.BlockSpec((1, S, tile_d), lambda bi, di: (bi, 0, di))
    sn_spec = pl.BlockSpec((1, S, N), lambda bi, di: (bi, 0, 0))
    y, h_last = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            sd_spec,                                             # x
            sd_spec,                                             # dt
            pl.BlockSpec((tile_d, N), lambda bi, di: (di, 0)),   # a_log
            sn_spec,                                             # b
            sn_spec,                                             # c
            pl.BlockSpec((1, tile_d), lambda bi, di: (0, di)),   # dskip
            pl.BlockSpec((1, tile_d, N), lambda bi, di: (bi, di, 0)),  # h0
        ],
        out_specs=[
            sd_spec,
            pl.BlockSpec((1, tile_d, N), lambda bi, di: (bi, di, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, D), x.dtype),
            jax.ShapeDtypeStruct((B, D, N), jnp.float32),
        ],
        interpret=interpret,
    )(x, dt, a_log, b, c, dskip.reshape(1, -1), h0)
    return y, h_last
