"""The paper's own experiment backbones (Appendix A.1), in pure JAX:

* ``mlp``       — MNIST:   200-200-10 fully connected.
* ``cnn``       — CIFAR-10: conv5x5(64) -> pool -> conv5x5(64) -> pool ->
                  fc384 -> fc192 -> classes.
* ``resnet18``  — CIFAR-100: ResNet-18 with GroupNorm replacing BatchNorm
                  (the paper swaps BN out because of its detrimental effect
                  under heterogeneous federated training).

These are the models the faithful-reproduction experiments federate; the
data is the synthetic stand-in (see DESIGN.md §2).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any


def _dense_init(rng, fan_in, fan_out):
    std = (2.0 / fan_in) ** 0.5
    return (jax.random.normal(rng, (fan_in, fan_out), jnp.float32) * std)


def _conv_init(rng, kh, kw, cin, cout):
    std = (2.0 / (kh * kw * cin)) ** 0.5
    return jax.random.normal(rng, (kh, kw, cin, cout), jnp.float32) * std


def conv2d(x, w, stride=1, padding="SAME"):
    """x: (B,H,W,C); w: (kh,kw,Cin,Cout)."""
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def max_pool(x, k=2, s=2):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                 (1, k, k, 1), (1, s, s, 1), "VALID")


def group_norm(x, scale, bias, groups=8, eps=1e-5):
    b, h, w, c = x.shape
    g = min(groups, c)
    while c % g:
        g -= 1
    xg = x.reshape(b, h, w, g, c // g)
    mean = xg.mean(axis=(1, 2, 4), keepdims=True)
    var = xg.var(axis=(1, 2, 4), keepdims=True)
    xg = (xg - mean) * jax.lax.rsqrt(var + eps)
    return xg.reshape(b, h, w, c) * scale + bias


# ---------------------------------------------------------------------------
# MLP (MNIST backbone)
# ---------------------------------------------------------------------------

def init_mlp(rng, in_dim=784, hidden=200, classes=10):
    ks = jax.random.split(rng, 3)
    return {"w1": _dense_init(ks[0], in_dim, hidden), "b1": jnp.zeros(hidden),
            "w2": _dense_init(ks[1], hidden, hidden), "b2": jnp.zeros(hidden),
            "w3": _dense_init(ks[2], hidden, classes),
            "b3": jnp.zeros(classes)}


def mlp_apply(params, x):
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ params["w1"] + params["b1"])
    x = jax.nn.relu(x @ params["w2"] + params["b2"])
    return x @ params["w3"] + params["b3"]


# ---------------------------------------------------------------------------
# CNN (CIFAR-10 backbone, Appendix A.1)
# ---------------------------------------------------------------------------

def init_cnn(rng, in_ch=3, classes=10, img=32):
    ks = jax.random.split(rng, 5)
    feat = (img // 4) ** 2 * 64
    return {
        "c1": _conv_init(ks[0], 5, 5, in_ch, 64), "cb1": jnp.zeros(64),
        "c2": _conv_init(ks[1], 5, 5, 64, 64), "cb2": jnp.zeros(64),
        "f1": _dense_init(ks[2], feat, 384), "fb1": jnp.zeros(384),
        "f2": _dense_init(ks[3], 384, 192), "fb2": jnp.zeros(192),
        "f3": _dense_init(ks[4], 192, classes), "fb3": jnp.zeros(classes),
    }


def cnn_apply(params, x):
    x = jax.nn.relu(conv2d(x, params["c1"]) + params["cb1"])
    x = max_pool(x)
    x = jax.nn.relu(conv2d(x, params["c2"]) + params["cb2"])
    x = max_pool(x)
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ params["f1"] + params["fb1"])
    x = jax.nn.relu(x @ params["f2"] + params["fb2"])
    return x @ params["f3"] + params["fb3"]


# ---------------------------------------------------------------------------
# ResNet-18 with GroupNorm (CIFAR-100 backbone)
# ---------------------------------------------------------------------------

_STAGES = ((64, 1), (128, 2), (256, 2), (512, 2))  # (channels, first stride)


def init_resnet18(rng, in_ch=3, classes=100):
    ks = iter(jax.random.split(rng, 64))
    params: dict = {
        "stem": _conv_init(next(ks), 3, 3, in_ch, 64),
        "stem_s": jnp.ones(64), "stem_b": jnp.zeros(64),
        "head": _dense_init(next(ks), 512, classes),
        "head_b": jnp.zeros(classes),
        "blocks": [],
    }
    cin = 64
    for cout, stride in _STAGES:
        for i in range(2):
            s = stride if i == 0 else 1
            blk = {
                "c1": _conv_init(next(ks), 3, 3, cin, cout),
                "n1s": jnp.ones(cout), "n1b": jnp.zeros(cout),
                "c2": _conv_init(next(ks), 3, 3, cout, cout),
                "n2s": jnp.ones(cout), "n2b": jnp.zeros(cout),
            }
            if s != 1 or cin != cout:
                blk["proj"] = _conv_init(next(ks), 1, 1, cin, cout)
                blk["projs"] = jnp.ones(cout)
                blk["projb"] = jnp.zeros(cout)
            params["blocks"].append(blk)
            cin = cout
    return params


def _block_stride(i: int) -> int:
    """Stride is structural (stage layout), not a parameter leaf — keeps
    the pytree jax-transform safe (vmap/broadcast over clients)."""
    return _STAGES[i // 2][1] if i % 2 == 0 else 1


def resnet18_apply(params, x):
    x = group_norm(conv2d(x, params["stem"]), params["stem_s"],
                   params["stem_b"])
    x = jax.nn.relu(x)
    for i, blk in enumerate(params["blocks"]):
        s = _block_stride(i)
        h = jax.nn.relu(group_norm(conv2d(x, blk["c1"], stride=s),
                                   blk["n1s"], blk["n1b"]))
        h = group_norm(conv2d(h, blk["c2"]), blk["n2s"], blk["n2b"])
        if "proj" in blk:
            x = group_norm(conv2d(x, blk["proj"], stride=s), blk["projs"],
                           blk["projb"])
        x = jax.nn.relu(x + h)
    x = x.mean(axis=(1, 2))
    return x @ params["head"] + params["head_b"]


BACKBONES = {
    "mlp": (init_mlp, mlp_apply),
    "cnn": (init_cnn, cnn_apply),
    "resnet18": (init_resnet18, resnet18_apply),
}


def build_vision(name: str, rng, **kw):
    init, apply = BACKBONES[name]
    params = init(rng, **kw)
    return params, apply


def vision_loss_fn(apply):
    def loss(params, batch, rng):
        logits = apply(params, batch["x"])
        logz = jax.nn.logsumexp(logits, -1)
        gold = jnp.take_along_axis(logits, batch["y"][..., None], -1)[..., 0]
        return jnp.mean(logz - gold)
    return loss
