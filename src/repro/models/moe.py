"""Mixture-of-Experts layer: top-k router + capacity-based dispatch/combine
einsums (the standard TPU formulation — no scatter/gather, MXU-friendly)
with a Switch-style load-balance auxiliary loss.

Covers both assigned MoE archs:
  * mixtral-8x7b      — 8 experts, top-2, per-expert tensor parallelism
  * qwen3-moe-235b    — 128 experts, top-8, expert-axis parallelism
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common
from repro.configs.base import ModelConfig


def init_moe_params(rng, cfg: ModelConfig, dtype):
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(rng, 4)
    return {
        "router": common.normal_init(ks[0], (d, e), d ** -0.5, jnp.float32),
        "w_gate": common.normal_init(ks[1], (e, d, ff), d ** -0.5, dtype),
        "w_up": common.normal_init(ks[2], (e, d, ff), d ** -0.5, dtype),
        "w_down": common.normal_init(ks[3], (e, ff, d), ff ** -0.5, dtype),
    }


def router_topk(logits: jax.Array, k: int):
    """logits (T, E) -> (weights (T,k), indices (T,k), probs (T,E)).

    Weights are softmax over the selected k (Mixtral/Qwen renormalise)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)
    weights = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
    return weights, top_i, probs


def load_balance_loss(probs: jax.Array, top_i: jax.Array, num_experts: int):
    """Switch-Transformer aux loss: E * sum_e f_e * p_e."""
    assign = jax.nn.one_hot(top_i[:, 0], num_experts, dtype=jnp.float32)
    f = jnp.mean(assign, axis=0)            # fraction routed (top-1 proxy)
    p = jnp.mean(probs, axis=0)             # mean router prob
    return num_experts * jnp.sum(f * p)


def moe_block(params, x, cfg: ModelConfig):
    """x: (B, S, D) -> (out, aux_loss).

    GShard-style grouped capacity dispatch.  Tokens are split into
    groups of <= cfg.moe_group_size; each group dispatches into its own
    expert buffers of capacity C = ceil(g * k * capacity_factor / E).
    Overflow within a group is dropped (contributes zero).

    Grouping matters: a single global group makes the dispatch/combine
    einsums O(T * E * C) = O(T^2 * k * cf) FLOPs — quadratic in tokens
    and 27x the expert matmul cost at 32k-token prefill (measured,
    EXPERIMENTS.md §Perf pair D).  With g-token groups the dispatch is
    O(T * E * c) with c ~ g*k*cf/E, a few % of the expert matmuls.
    """
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    t = b * s
    g = min(cfg.moe_group_size or t, t)
    while t % g != 0:        # largest divisor of T not above the target
        g -= 1
    n_groups = t // g
    cap = int(max(1, round(g * k * cfg.capacity_factor / e)))
    # round capacity to an MXU-friendly multiple of 8 where possible
    cap = max(8, (cap + 7) // 8 * 8) if g >= 64 else cap

    xg = x.reshape(n_groups, g, d)                               # (G,g,D)
    weights, top_i, probs = router_topk(
        jnp.einsum("gtd,de->gte", xg, params["router"]), k)      # (G,g,k)
    aux = load_balance_loss(probs.reshape(t, e),
                            top_i.reshape(t, k), e) * cfg.moe_aux_coef

    # position of each (token, choice) in its expert's buffer, per group
    onehot = jax.nn.one_hot(top_i, e, dtype=jnp.int32)           # (G,g,k,E)
    flat = onehot.reshape(n_groups, g * k, e)
    pos_in_expert = jnp.cumsum(flat, axis=1) - flat              # (G,g*k,E)
    pos = jnp.sum(flat * pos_in_expert, axis=-1).reshape(n_groups, g, k)
    keep = pos < cap
    w = weights * keep.astype(weights.dtype)

    # dispatch tensor (G, g, E, C): w if token t goes to slot (e, c)
    slot = jax.nn.one_hot(pos, cap, dtype=jnp.float32)           # (G,g,k,C)
    oh = onehot.astype(jnp.float32)
    disp = jnp.einsum("gtke,gtkc->gtec", oh,
                      slot * keep[..., None].astype(jnp.float32))
    comb = jnp.einsum("gtke,gtkc,gtk->gtec", oh, slot, w.astype(jnp.float32))

    expert_in = jnp.einsum("gtec,gtd->gecd", disp,
                           xg.astype(jnp.float32)).astype(x.dtype)
    gate = jax.nn.silu(jnp.einsum("gecd,edf->gecf", expert_in,
                                  params["w_gate"]).astype(jnp.float32))
    up = jnp.einsum("gecd,edf->gecf", expert_in,
                    params["w_up"]).astype(jnp.float32)
    act = (gate * up).astype(x.dtype)
    expert_out = jnp.einsum("gecf,efd->gecd", act, params["w_down"])

    out = jnp.einsum("gtec,gecd->gtd", comb, expert_out.astype(jnp.float32))
    return out.reshape(b, s, d).astype(x.dtype), aux


def moe_block_dense_ref(params, x, cfg: ModelConfig):
    """Oracle: run EVERY expert on every token and combine with the exact
    top-k weights (no capacity drops).  O(E x) compute — tests only."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    xt = x.reshape(b * s, d)
    weights, top_i, _ = router_topk(xt @ params["router"], k)
    gate = jax.nn.silu(jnp.einsum("td,edf->etf", xt, params["w_gate"]
                                  ).astype(jnp.float32))
    up = jnp.einsum("td,edf->etf", xt, params["w_up"]).astype(jnp.float32)
    outs = jnp.einsum("etf,efd->etd", (gate * up).astype(x.dtype),
                      params["w_down"])                          # (E,T,D)
    mask = jax.nn.one_hot(top_i, e, dtype=jnp.float32) * weights[..., None]
    w_e = jnp.sum(mask, axis=1)                                  # (T,E)
    out = jnp.einsum("te,etd->td", w_e, outs.astype(jnp.float32))
    return out.reshape(b, s, d).astype(x.dtype)
