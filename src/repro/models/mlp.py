"""Gated MLP (SwiGLU) used by all attention architectures."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common


def init_mlp_params(rng, d_model: int, d_ff: int, dtype):
    ks = jax.random.split(rng, 3)
    return {
        "w_gate": common.normal_init(ks[0], (d_model, d_ff), d_model ** -0.5, dtype),
        "w_up": common.normal_init(ks[1], (d_model, d_ff), d_model ** -0.5, dtype),
        "w_down": common.normal_init(ks[2], (d_ff, d_model), d_ff ** -0.5, dtype),
    }


def mlp_block(params, x):
    gate = jax.nn.silu((x @ params["w_gate"]).astype(jnp.float32))
    up = (x @ params["w_up"]).astype(jnp.float32)
    return ((gate * up).astype(x.dtype)) @ params["w_down"]
