"""GQA/MQA attention with RoPE, sliding windows, prefix-LM masks and a
KV cache decode path (incl. a shard_map flash-decode for long contexts).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import common
from repro.configs.base import ModelConfig

FULL_WINDOW = 1 << 30  # "no window" sentinel large enough for any seq


def init_attn_params(rng, cfg: ModelConfig, dtype):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, kv = cfg.num_heads, cfg.num_kv_heads
    ks = jax.random.split(rng, 4)
    std = d ** -0.5
    return {
        "wq": common.normal_init(ks[0], (d, h * hd), std, dtype),
        "wk": common.normal_init(ks[1], (d, kv * hd), std, dtype),
        "wv": common.normal_init(ks[2], (d, kv * hd), std, dtype),
        "wo": common.normal_init(ks[3], (h * hd, d), (h * hd) ** -0.5, dtype),
    }


def _split_heads(x, n, hd):
    return x.reshape(x.shape[:-1] + (n, hd))


def _repeat_kv(k, groups):
    """(B,S,KV,hd) -> (B,S,KV*groups,hd) by repeating each kv head."""
    if groups == 1:
        return k
    b, s, kv, hd = k.shape
    k = jnp.broadcast_to(k[:, :, :, None, :], (b, s, kv, groups, hd))
    return k.reshape(b, s, kv * groups, hd)


def attend(q, k, v, mask):
    """q: (B,Sq,H,hd), k/v: (B,Sk,H,hd), mask: (B,Sq,Sk) or (Sq,Sk) bool."""
    hd = q.shape[-1]
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / (hd ** 0.5)
    if mask.ndim == 2:
        mask = mask[None]
    scores = jnp.where(mask[:, None], scores, common.NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v.astype(jnp.float32)
                      ).astype(q.dtype)


def attention_block(params, x, positions, cfg: ModelConfig, *,
                    window=FULL_WINDOW, prefix_len: int = 0):
    """Self-attention over a full sequence (training / prefill).

    x: (B, S, D); positions: (B, S) or (S,).
    """
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = _split_heads(x @ params["wq"], h, hd)
    k = _split_heads(x @ params["wk"], kv, hd)
    v = _split_heads(x @ params["wv"], kv, hd)
    if positions.ndim == 1:
        positions = positions[None]
    q = common.apply_rope(q, positions, cfg.rope_theta)
    k = common.apply_rope(k, positions, cfg.rope_theta)
    mask = common.attention_mask(positions, positions, window=window,
                                 prefix_len=prefix_len)
    out = attend(q, _repeat_kv(k, h // kv), _repeat_kv(v, h // kv), mask)
    return out.reshape(out.shape[:2] + (h * hd,)) @ params["wo"], (k, v)


# ---------------------------------------------------------------------------
# Decode (one token against a cache)
# ---------------------------------------------------------------------------

def decode_attention(params, x, cache_k, cache_v, pos, cfg: ModelConfig, *,
                     window=FULL_WINDOW, sharded_kv_axis: str | None = None):
    """x: (B, 1, D). cache_k/v: (B, S_max, KV, hd) with entries valid < pos.

    Writes the new token's k/v at ``pos`` and attends over the cache.
    ``sharded_kv_axis``: if set, run flash-decode under shard_map with the
    cache sequence axis sharded over that mesh axis (long-context path).
    Returns (out (B,1,D), new_k, new_v).
    """
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    b = x.shape[0]
    q = _split_heads(x @ params["wq"], h, hd)
    knew = _split_heads(x @ params["wk"], kv, hd)
    vnew = _split_heads(x @ params["wv"], kv, hd)
    posb = jnp.broadcast_to(pos.reshape(-1, 1), (b, 1))
    q = common.apply_rope(q, posb, cfg.rope_theta)
    knew = common.apply_rope(knew, posb, cfg.rope_theta)

    if sharded_kv_axis is None:
        cache_k = jax.lax.dynamic_update_slice_in_dim(
            cache_k, knew.astype(cache_k.dtype), pos, axis=1)
        cache_v = jax.lax.dynamic_update_slice_in_dim(
            cache_v, vnew.astype(cache_v.dtype), pos, axis=1)
        out = _decode_attend(q, cache_k, cache_v, pos, h // kv, window,
                             kpos_offset=0)
    else:
        # shard-aware cache write: only the shard owning ``pos`` commits.
        s_local = cache_k.shape[1]
        idx = jax.lax.axis_index(sharded_kv_axis)
        local_pos = pos - idx * s_local
        safe_pos = jnp.clip(local_pos, 0, s_local - 1)
        owner = (local_pos >= 0) & (local_pos < s_local)
        upd_k = jax.lax.dynamic_update_slice_in_dim(
            cache_k, knew.astype(cache_k.dtype), safe_pos, axis=1)
        upd_v = jax.lax.dynamic_update_slice_in_dim(
            cache_v, vnew.astype(cache_v.dtype), safe_pos, axis=1)
        cache_k = jnp.where(owner, upd_k, cache_k)
        cache_v = jnp.where(owner, upd_v, cache_v)
        out = _flash_decode_sharded(q, cache_k, cache_v, pos, h // kv, window,
                                    sharded_kv_axis)
    return out.reshape(b, 1, h * hd) @ params["wo"], cache_k, cache_v


def _decode_attend(q, ck, cv, pos, groups, window, kpos_offset):
    """q (B,1,H,hd) vs cache (B,S,KV,hd); masked by validity & window.

    Grouped-GQA form: q is reshaped to (B,1,KV,G,hd) and contracted
    against the cache directly — no materialized `_repeat_kv` copy — and
    the f32 accumulation happens inside the dot (preferred_element_type)
    instead of via explicit f32 casts of the S-sized cache reads.
    """
    b, s, kvh, hd = ck.shape
    q = q.reshape(b, 1, kvh, groups, hd)
    kpos = jnp.arange(s) + kpos_offset
    valid = (kpos <= pos) & ((pos - kpos) < window)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", q, ck,
                        preferred_element_type=jnp.float32) / (hd ** 0.5)
    scores = jnp.where(valid[None, None, None, None, :], scores,
                       common.NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, cv,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, kvh * groups, hd).astype(q.dtype)


def _flash_decode_sharded(q, ck, cv, pos, groups, window, axis_name):
    """Flash-decode combine: each shard of the cache computes a partial
    (max, sum-exp, weighted-V) triple over its sequence slice; shards are
    combined with a numerically-stable log-sum-exp psum.  Collective bytes:
    O(B*H*hd) instead of all-gathering the O(B*S*KV*hd) cache.

    Must be called with ``axis_name`` bound (inside shard_map) and ck/cv
    holding only the local sequence slice.
    """
    b, s_local, _, hd = ck.shape
    idx = jax.lax.axis_index(axis_name)
    kpos_offset = idx * s_local
    k = _repeat_kv(ck, groups)
    v = _repeat_kv(cv, groups)
    kpos = jnp.arange(s_local) + kpos_offset
    valid = (kpos <= pos) & ((pos - kpos) < window)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / (hd ** 0.5)
    scores = jnp.where(valid[None, None, None, :], scores, common.NEG_INF)
    local_max = jnp.max(scores, axis=-1)                       # (B,H,1)
    gmax = jax.lax.pmax(local_max, axis_name)
    exp = jnp.exp(scores - gmax[..., None])
    denom = jax.lax.psum(jnp.sum(exp, axis=-1), axis_name)     # (B,H,1)
    weighted = jnp.einsum("bhqk,bkhd->bqhd", exp, v.astype(jnp.float32))
    numer = jax.lax.psum(weighted, axis_name)                  # (B,1,H,hd)
    out = numer / jnp.swapaxes(denom, 1, 2)[..., None]
    return out.astype(q.dtype)


def flash_decode_call(params, x, cache_k, cache_v, pos, cfg: ModelConfig,
                      mesh, seq_axis: str, window=FULL_WINDOW):
    """shard_map wrapper for one decode-attention call with the cache's
    sequence axis sharded over ``seq_axis``.  x/pos replicated."""
    def body(params_, x_, ck, cv, pos_):
        out, nk, nv = decode_attention(params_, x_, ck, cv, pos_, cfg,
                                       window=window, sharded_kv_axis=seq_axis)
        return out, nk, nv

    pspec = jax.tree.map(lambda _: P(), params)
    cache_spec = P(None, seq_axis, None, None)
    return jax.shard_map(
        body, mesh=mesh,
        in_specs=(pspec, P(), cache_spec, cache_spec, P()),
        out_specs=(P(), cache_spec, cache_spec),
        check_vma=False,
    )(params, x, cache_k, cache_v, pos)
