"""Model assembly: one functional Model API for all assigned architectures.

Design notes
------------
* Layer parameters are STACKED (leading ``L`` axis) and the stack is applied
  with ``lax.scan`` — keeps the HLO size O(1) in depth (essential for the
  126-layer llama3-405b dry-run).
* Local/global attention (gemma3 5:1), sliding windows (mixtral) and full
  attention share ONE code path: a per-layer ``window`` scalar fed through
  the scan; ``FULL_WINDOW`` disables windowing.
* Hybrid (zamba2) runs a flat scan over Mamba-2 layers and applies the
  single SHARED attention block after every ``hybrid_attn_every``-th layer
  via ``lax.cond`` (same shared params each application, distinct KV cache
  slice per application at decode time).
* ``vlm``/``audio`` consume precomputed frontend embeddings per the spec.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention, common, mamba, mlp, moe
from repro.models.attention import FULL_WINDOW

PyTree = Any


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_attn_block(rng, cfg: ModelConfig, dtype, with_moe: bool):
    ks = jax.random.split(rng, 3)
    p = {
        "attn": attention.init_attn_params(ks[0], cfg, dtype),
        "ln1": jnp.zeros((cfg.d_model,), dtype),
        "ln2": jnp.zeros((cfg.d_model,), dtype),
    }
    if with_moe:
        p["moe"] = moe.init_moe_params(ks[1], cfg, dtype)
    else:
        p["mlp"] = mlp.init_mlp_params(ks[1], cfg.d_model, cfg.d_ff, dtype)
    return p


def _init_ssm_block(rng, cfg: ModelConfig, dtype):
    ks = jax.random.split(rng, 2)
    init = (mamba.init_mamba1_params if cfg.ssm_variant == "mamba1"
            else mamba.init_mamba2_params)
    return {"mixer": init(ks[0], cfg, dtype),
            "ln": jnp.zeros((cfg.d_model,), dtype)}


def init_params(cfg: ModelConfig, rng: jax.Array) -> PyTree:
    dtype = common.dtype_of(cfg.dtype)
    k_embed, k_layers, k_head, k_shared = jax.random.split(rng, 4)
    layer_keys = jax.random.split(k_layers, cfg.num_layers)

    if cfg.arch_type in ("dense", "vlm", "audio"):
        def layer_init(k):
            return _init_attn_block(k, cfg, dtype, with_moe=False)
    elif cfg.arch_type == "moe":
        def layer_init(k):
            return _init_attn_block(k, cfg, dtype, with_moe=True)
    else:  # ssm / hybrid
        def layer_init(k):
            return _init_ssm_block(k, cfg, dtype)

    params: dict = {
        "layers": jax.vmap(layer_init)(layer_keys),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
        "lm_head": common.normal_init(k_head, (cfg.d_model, cfg.vocab_size),
                                      cfg.d_model ** -0.5, dtype),
    }
    if cfg.arch_type != "audio":  # audio consumes frame embeddings directly
        params["embed"] = common.normal_init(
            k_embed, (cfg.vocab_size, cfg.d_model), 1.0, dtype)
    if cfg.arch_type == "hybrid" and cfg.hybrid_attn_every:
        params["shared_attn"] = _init_attn_block(k_shared, cfg, dtype,
                                                 with_moe=False)
    return params


def param_shapes(cfg: ModelConfig) -> PyTree:
    """ShapeDtypeStruct pytree without allocating (dry-run path)."""
    return jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# Per-layer window schedule (static, host-side)
# ---------------------------------------------------------------------------

def layer_windows(cfg: ModelConfig) -> jnp.ndarray:
    """(L,) int32 attention window per layer (FULL_WINDOW = unbounded)."""
    L = cfg.num_layers
    if cfg.local_global_ratio > 0:
        r = cfg.local_global_ratio
        win = [cfg.sliding_window if (i % (r + 1)) != r else FULL_WINDOW
               for i in range(L)]
    elif cfg.sliding_window > 0:
        win = [cfg.sliding_window] * L
    else:
        win = [FULL_WINDOW] * L
    return jnp.asarray(win, jnp.int32)


# ---------------------------------------------------------------------------
# Forward (training / scoring)
# ---------------------------------------------------------------------------

def _attn_mlp_layer(layer_params, x, positions, window, cfg: ModelConfig):
    """Pre-norm attention block; returns (x, aux, (k, v))."""
    h = common.rms_norm(x, layer_params["ln1"], cfg.norm_eps)
    a, kv = attention.attention_block(layer_params["attn"], h, positions, cfg,
                                      window=window,
                                      prefix_len=cfg.prefix_tokens)
    x = x + a
    h = common.rms_norm(x, layer_params["ln2"], cfg.norm_eps)
    if cfg.arch_type == "moe":
        m, aux = moe.moe_block(layer_params["moe"], h, cfg)
    else:
        m, aux = mlp.mlp_block(layer_params["mlp"], h), 0.0
    return x + m, aux, kv


def _ssm_layer(layer_params, x, cfg: ModelConfig, ssm_state=None,
               conv_state=None):
    h = common.rms_norm(x, layer_params["ln"], cfg.norm_eps)
    block = mamba.mamba1_block if cfg.ssm_variant == "mamba1" else \
        mamba.mamba2_block
    y, states = block(layer_params["mixer"], h, cfg, ssm_state, conv_state)
    return x + y, states


def embed_inputs(params, cfg: ModelConfig, batch: dict) -> jax.Array:
    """Tokens and/or frontend embeddings -> (B, S, D) hidden input."""
    dtype = common.dtype_of(cfg.dtype)
    if cfg.arch_type == "audio":
        return batch["embeds"].astype(dtype)
    tok = params["embed"][batch["tokens"]]
    if cfg.arch_type == "vlm":
        prefix = batch["embeds"].astype(dtype)          # (B, P, D) patch embeds
        return jnp.concatenate([prefix, tok], axis=1)
    return tok


def forward(params, cfg: ModelConfig, batch: dict, *, remat: bool = False
            ) -> tuple[jax.Array, jax.Array]:
    """-> (final hidden (B,S,D), aux_loss scalar)."""
    x = embed_inputs(params, cfg, batch)
    B, S = x.shape[0], x.shape[1]
    positions = jnp.arange(S)[None]
    aux0 = jnp.zeros((), jnp.float32)

    if cfg.arch_type in ("dense", "moe", "vlm", "audio"):
        windows = layer_windows(cfg)

        def body(carry, inp):
            xc, aux = carry
            lp, win = inp
            xn, a, _ = _attn_mlp_layer(lp, xc, positions, win, cfg)
            return (xn, aux + a), None

        if remat:
            body = jax.checkpoint(body)
        (x, aux), _ = jax.lax.scan(body, (x, aux0),
                                   (params["layers"], windows))

    elif cfg.arch_type == "ssm":
        def body(xc, lp):
            xn, _ = _ssm_layer(lp, xc, cfg)
            return xn, None

        if remat:
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, x, params["layers"])
        aux = aux0

    else:  # hybrid
        every = cfg.hybrid_attn_every
        shared = params.get("shared_attn")

        def body(carry, inp):
            xc, idx = carry
            lp = inp
            xn, _ = _ssm_layer(lp, xc, cfg)

            def with_attn(xh):
                xh2, _, _ = _attn_mlp_layer(shared, xh, positions,
                                            FULL_WINDOW, cfg)
                return xh2

            fire = (every > 0) & (jnp.mod(idx + 1, every) == 0)
            xn = jax.lax.cond(fire, with_attn, lambda h: h, xn)
            return (xn, idx + 1), None

        if remat:
            body = jax.checkpoint(body)
        (x, _), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.int32)),
                                 params["layers"])
        aux = aux0

    return common.rms_norm(x, params["final_norm"], cfg.norm_eps), aux


def logits_fn(params, cfg: ModelConfig, batch: dict) -> jax.Array:
    h, _ = forward(params, cfg, batch)
    return h @ params["lm_head"]


def loss_fn(params, cfg: ModelConfig, batch: dict, rng=None, *,
            remat: bool = False) -> jax.Array:
    """Next-token cross-entropy (+ MoE aux).  Expects batch["labels"];
    ``label_mask`` optional (VLM: loss only on text suffix)."""
    h, aux = forward(params, cfg, batch, remat=remat)
    labels = batch["labels"]
    mask = batch.get("label_mask")
    if cfg.arch_type == "vlm":
        # hidden covers prefix+text; labels only cover the text part
        h = h[:, cfg.prefix_tokens:]
    if cfg.loss_chunk > 0:
        ce = common.chunked_cross_entropy(h, params["lm_head"], labels, mask,
                                          chunk=cfg.loss_chunk)
    else:
        ce = common.cross_entropy(h @ params["lm_head"], labels, mask)
    return ce + aux


# ---------------------------------------------------------------------------
# KV / SSM caches
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch_size: int, max_seq: int) -> PyTree:
    dtype = common.dtype_of(cfg.dtype)
    L, hd = cfg.num_layers, cfg.resolved_head_dim
    cache: dict = {"pos": jnp.zeros((), jnp.int32)}
    if cfg.arch_type in ("dense", "moe", "vlm", "audio"):
        kvshape = (L, batch_size, max_seq, cfg.num_kv_heads, hd)
        cache["k"] = jnp.zeros(kvshape, dtype)
        cache["v"] = jnp.zeros(kvshape, dtype)
    elif cfg.arch_type == "ssm":
        cache["ssm"] = jnp.zeros((L, batch_size, cfg.d_inner, cfg.ssm_state),
                                 jnp.float32)
        cache["conv"] = jnp.zeros((L, batch_size, cfg.d_conv - 1, cfg.d_inner),
                                  dtype)
    else:  # hybrid
        napps = cfg.num_layers // max(cfg.hybrid_attn_every, 1)
        cache["ssm"] = jnp.zeros((L, batch_size, cfg.ssm_heads,
                                  cfg.ssm_head_dim, cfg.ssm_state), jnp.float32)
        cache["conv"] = jnp.zeros((L, batch_size, cfg.d_conv - 1, cfg.d_inner),
                                  dtype)
        kvshape = (napps, batch_size, max_seq, cfg.num_kv_heads, hd)
        cache["k"] = jnp.zeros(kvshape, dtype)
        cache["v"] = jnp.zeros(kvshape, dtype)
    return cache


def cache_shapes(cfg: ModelConfig, batch_size: int, max_seq: int) -> PyTree:
    return jax.eval_shape(lambda: init_cache(cfg, batch_size, max_seq))


# ---------------------------------------------------------------------------
# Prefill
# ---------------------------------------------------------------------------

def prefill(params, cfg: ModelConfig, batch: dict, max_seq: int):
    """Run the prompt, return (last-token logits (B,V), filled cache)."""
    x = embed_inputs(params, cfg, batch)
    B, S = x.shape[0], x.shape[1]
    positions = jnp.arange(S)[None]
    cache = init_cache(cfg, B, max_seq)

    if cfg.arch_type in ("dense", "moe", "vlm", "audio"):
        windows = layer_windows(cfg)

        def body(xc, inp):
            lp, win = inp
            xn, _, (k, v) = _attn_mlp_layer(lp, xc, positions, win, cfg)
            return xn, (k, v)

        x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], windows))
        cache["k"] = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], ks.astype(cache["k"].dtype), 0, axis=2)
        cache["v"] = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], vs.astype(cache["v"].dtype), 0, axis=2)

    elif cfg.arch_type == "ssm":
        def body(xc, lp):
            xn, (h, conv) = _ssm_layer(lp, xc, cfg)
            return xn, (h, conv)

        x, (hs, convs) = jax.lax.scan(body, x, params["layers"])
        cache["ssm"], cache["conv"] = hs, convs.astype(cache["conv"].dtype)

    else:  # hybrid
        every = cfg.hybrid_attn_every
        shared = params.get("shared_attn")

        def body(carry, lp):
            xc, idx, app, ck, cv = carry
            xn, (h, conv) = _ssm_layer(lp, xc, cfg)

            def with_attn(args):
                xh, app_, ck_, cv_ = args
                hn = common.rms_norm(xh, shared["ln1"], cfg.norm_eps)
                a, (k, v) = attention.attention_block(
                    shared["attn"], hn, positions, cfg, window=FULL_WINDOW)
                xh = xh + a
                hn = common.rms_norm(xh, shared["ln2"], cfg.norm_eps)
                xh = xh + mlp.mlp_block(shared["mlp"], hn)
                pad_k = jnp.zeros_like(ck_[0])
                pad_k = jax.lax.dynamic_update_slice_in_dim(
                    pad_k, k.astype(pad_k.dtype), 0, axis=1)
                pad_v = jnp.zeros_like(cv_[0])
                pad_v = jax.lax.dynamic_update_slice_in_dim(
                    pad_v, v.astype(pad_v.dtype), 0, axis=1)
                ck_ = jax.lax.dynamic_update_slice_in_dim(
                    ck_, pad_k[None], app_, axis=0)
                cv_ = jax.lax.dynamic_update_slice_in_dim(
                    cv_, pad_v[None], app_, axis=0)
                return xh, app_ + 1, ck_, cv_

            fire = (every > 0) & (jnp.mod(idx + 1, every) == 0)
            xn, app, ck, cv = jax.lax.cond(
                fire, with_attn, lambda a: a, (xn, app, ck, cv))
            return (xn, idx + 1, app, ck, cv), (h, conv)

        init = (x, jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32),
                cache["k"], cache["v"])
        (x, _, _, ck, cv), (hs, convs) = jax.lax.scan(body, init,
                                                      params["layers"])
        cache["ssm"], cache["conv"] = hs, convs.astype(cache["conv"].dtype)
        cache["k"], cache["v"] = ck, cv

    cache["pos"] = jnp.asarray(S, jnp.int32)
    h = common.rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    return (h @ params["lm_head"])[:, 0], cache


# ---------------------------------------------------------------------------
# Decode step (one token)
# ---------------------------------------------------------------------------

def decode_step(params, cfg: ModelConfig, cache: PyTree, token: jax.Array,
                *, mesh=None, flash_axis: str | None = None):
    """token: (B,) int32 (or (B,1,D) embeds for audio).  Returns
    (logits (B,V), new cache).  ``flash_axis``: mesh axis holding the KV
    cache sequence shards (long-context shard_map flash decode)."""
    dtype = common.dtype_of(cfg.dtype)
    pos = cache["pos"]
    if cfg.arch_type == "audio":
        x = token.astype(dtype)            # (B,1,D) frame embedding
    else:
        x = params["embed"][token][:, None] if token.ndim == 1 else \
            params["embed"][token]
    windows = layer_windows(cfg) if cfg.uses_attention else None

    def attn_decode(lp, xc, ck, cv, win):
        h = common.rms_norm(xc, lp["ln1"], cfg.norm_eps)
        if flash_axis is not None:
            a, nk, nv = attention.flash_decode_call(
                lp["attn"], h, ck, cv, pos, cfg, mesh, flash_axis, window=win)
        else:
            a, nk, nv = attention.decode_attention(
                lp["attn"], h, ck, cv, pos, cfg, window=win)
        xc = xc + a
        h = common.rms_norm(xc, lp["ln2"], cfg.norm_eps)
        if cfg.arch_type == "moe":
            mo, _ = moe.moe_block(lp["moe"], h, cfg)
        else:
            mo = mlp.mlp_block(lp["mlp"], h)
        return xc + mo, nk, nv

    if cfg.arch_type in ("dense", "moe", "vlm", "audio"):
        def body(xc, inp):
            lp, ck, cv, win = inp
            xn, nk, nv = attn_decode(lp, xc, ck, cv, win)
            return xn, (nk, nv)

        x, (nk, nv) = jax.lax.scan(body, x, (params["layers"], cache["k"],
                                             cache["v"], windows))
        cache = dict(cache, k=nk, v=nv)

    elif cfg.arch_type == "ssm":
        step = (mamba.mamba1_decode_step if cfg.ssm_variant == "mamba1"
                else mamba.mamba2_decode_step)

        def body(xc, inp):
            lp, h, conv = inp
            hn = common.rms_norm(xc, lp["ln"], cfg.norm_eps)
            y, nh, nconv = step(lp["mixer"], hn, h, conv, cfg)
            return xc + y, (nh, nconv.astype(conv.dtype))

        x, (nh, nconv) = jax.lax.scan(body, x, (params["layers"],
                                                cache["ssm"], cache["conv"]))
        cache = dict(cache, ssm=nh, conv=nconv)

    else:  # hybrid
        every = cfg.hybrid_attn_every
        shared = params.get("shared_attn")

        def body(carry, inp):
            xc, idx, app, ck, cv = carry
            lp, h, conv = inp
            hn = common.rms_norm(xc, lp["ln"], cfg.norm_eps)
            y, nh, nconv = mamba.mamba2_decode_step(lp["mixer"], hn, h, conv,
                                                    cfg)
            xn = xc + y

            def with_attn(args):
                xh, app_, ck_, cv_ = args
                hs = common.rms_norm(xh, shared["ln1"], cfg.norm_eps)
                ck_l = jax.lax.dynamic_index_in_dim(ck_, app_, 0, False)
                cv_l = jax.lax.dynamic_index_in_dim(cv_, app_, 0, False)
                a, nk, nv = attention.decode_attention(
                    shared["attn"], hs, ck_l, cv_l, pos, cfg,
                    window=FULL_WINDOW)
                ck_ = jax.lax.dynamic_update_slice_in_dim(ck_, nk[None], app_,
                                                          axis=0)
                cv_ = jax.lax.dynamic_update_slice_in_dim(cv_, nv[None], app_,
                                                          axis=0)
                xh = xh + a
                hs = common.rms_norm(xh, shared["ln2"], cfg.norm_eps)
                xh = xh + mlp.mlp_block(shared["mlp"], hs)
                return xh, app_ + 1, ck_, cv_

            fire = (every > 0) & (jnp.mod(idx + 1, every) == 0)
            xn, app, ck, cv = jax.lax.cond(fire, with_attn, lambda a: a,
                                           (xn, app, ck, cv))
            return (xn, idx + 1, app, ck, cv), (nh, nconv.astype(conv.dtype))

        init = (x, jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32),
                cache["k"], cache["v"])
        (x, _, _, ck, cv), (nh, nconv) = jax.lax.scan(
            body, init, (params["layers"], cache["ssm"], cache["conv"]))
        cache = dict(cache, ssm=nh, conv=nconv, k=ck, v=cv)

    cache["pos"] = pos + 1
    h = common.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return (h @ params["lm_head"])[:, 0], cache


# ---------------------------------------------------------------------------
# Model bundle
# ---------------------------------------------------------------------------

class Model(NamedTuple):
    cfg: ModelConfig
    init: Any
    forward: Any
    loss: Any
    prefill: Any
    decode_step: Any
    init_cache: Any

    def param_count(self, params=None) -> int:
        tree = params if params is not None else param_shapes(self.cfg)
        import numpy as np
        return int(sum(np.prod(x.shape) for x in jax.tree.leaves(tree)))


def build_model(cfg: ModelConfig) -> Model:
    return Model(
        cfg=cfg,
        init=functools.partial(init_params, cfg),
        forward=functools.partial(forward, cfg=cfg),
        loss=lambda params, batch, rng=None, **kw: loss_fn(params, cfg, batch,
                                                           rng, **kw),
        prefill=lambda params, batch, max_seq: prefill(params, cfg, batch,
                                                       max_seq),
        decode_step=lambda params, cache, token, **kw: decode_step(
            params, cfg, cache, token, **kw),
        init_cache=functools.partial(init_cache, cfg),
    )
