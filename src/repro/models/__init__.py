"""Model substrate: every assigned architecture as a functional JAX model."""
from repro.models.model import (Model, build_model, cache_shapes, decode_step,
                                forward, init_cache, init_params, loss_fn,
                                logits_fn, param_shapes, prefill)
