"""Mamba-1 (selective scan, falcon-mamba) and Mamba-2 (SSD scalar-decay,
zamba2) blocks in pure JAX.

Training/prefill uses a *chunked* linear-recurrence scan:
``lax.scan`` over sequence chunks carrying the state, with
``lax.associative_scan`` inside each chunk.  This bounds the materialised
(B, chunk, d_inner, N) tensor instead of (B, S, d_inner, N) — the TPU
adaptation of the CUDA fused selective-scan kernel (see DESIGN.md §3).

Decode is the exact O(1)-state recurrence step (tested against the scan).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common
from repro.configs.base import ModelConfig


def _affine_combine(e1, e2):
    """Compose affine recurrences h -> a*h + b."""
    a1, b1 = e1
    a2, b2 = e2
    return a2 * a1, a2 * b1 + b2


def _chunk_split(x, chunk: int):
    """(B, S, ...) -> (n, B, cs, ...) scan-ready chunks (largest cs <= chunk
    dividing S)."""
    B, S = x.shape[0], x.shape[1]
    n = max(1, S // chunk)
    while S % n != 0:
        n -= 1
    cs = S // n
    return jnp.moveaxis(x.reshape((B, n, cs) + x.shape[2:]), 1, 0)


def _chunk_merge(x_chunks):
    """(n, B, cs, ...) -> (B, S, ...)."""
    n, B, cs = x_chunks.shape[0], x_chunks.shape[1], x_chunks.shape[2]
    return jnp.moveaxis(x_chunks, 0, 1).reshape((B, n * cs) + x_chunks.shape[3:])


def chunked_linear_scan(a, b, h0, chunk: int):
    """Run h_t = a_t * h_{t-1} + b_t along axis 1 (time).

    a, b: (B, S, ...) broadcast-compatible; h0: (B, ...).
    Returns (h_all (B,S,...), h_last (B,...)).

    NOTE: materializes h for every position — O(S * state) HBM.  The
    model blocks below instead run ``chunked_ssm`` which keeps the
    per-position state inside the chunk body (only y and the boundary
    states ever hit HBM); this function remains the reference oracle
    (tests/test_mamba.py) and the small-shape path.
    """
    a_c = _chunk_split(jnp.broadcast_to(a, b.shape), chunk)
    b_c = _chunk_split(b, chunk)

    def body(h, inp):
        ac, bc = inp
        a_cum, b_cum = jax.lax.associative_scan(_affine_combine, (ac, bc), axis=1)
        h_all = a_cum * h[:, None] + b_cum
        return h_all[:, -1], h_all

    h_last, h_chunks = jax.lax.scan(body, h0, (a_c, b_c))
    return _chunk_merge(h_chunks), h_last


def chunked_ssm(ab_fn, y_fn, chunk_inputs, h0, chunk: int):
    """Chunked SSM driver that never materializes (B, S, state) in HBM.

    ``chunk_inputs``: pytree of (B, S, ...) tensors, split into scan
    chunks.  Per chunk the body computes a/b via ``ab_fn(inputs_chunk)``,
    runs the in-chunk associative scan, reduces the states to the output
    via ``y_fn(h_all, inputs_chunk)`` and carries only the boundary
    state.  HBM sees the chunked inputs, the y output and one state per
    chunk boundary — the TPU analogue of the fused CUDA selective scan.
    """
    xs = jax.tree.map(lambda t: _chunk_split(t, chunk), chunk_inputs)

    @jax.checkpoint
    def body(h, inp):
        a, b = ab_fn(inp)
        a_cum, b_cum = jax.lax.associative_scan(
            _affine_combine, (jnp.broadcast_to(a, b.shape), b), axis=1)
        h_all = a_cum * h[:, None] + b_cum
        return h_all[:, -1], y_fn(h_all, inp)

    # checkpointed body: the backward pass recomputes the (B, c, ..., N)
    # in-chunk states from the tiny carried boundary state instead of
    # keeping one h_all per chunk alive for the whole layer.
    h_last, y_chunks = jax.lax.scan(body, h0, xs)
    return _chunk_merge(y_chunks), h_last


def causal_conv1d(x, w, b):
    """Depthwise causal conv.  x: (B,S,C), w: (Kw,C), b: (C,)."""
    kw = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (kw - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(kw))
    return out + b


def conv_step(conv_state, xt, w, b):
    """Single-token causal conv.  conv_state: (B,Kw-1,C) last inputs;
    xt: (B,1,C).  Returns (yt, new_state)."""
    window = jnp.concatenate([conv_state, xt], axis=1)        # (B,Kw,C)
    yt = jnp.einsum("bkc,kc->bc", window, w)[:, None] + b
    return yt, window[:, 1:]


# ---------------------------------------------------------------------------
# Mamba-1 (falcon-mamba-7b)
# ---------------------------------------------------------------------------

def init_mamba1_params(rng, cfg: ModelConfig, dtype):
    d, di, st, dr = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank
    ks = jax.random.split(rng, 6)
    a_init = jnp.log(jnp.broadcast_to(jnp.arange(1, st + 1, dtype=jnp.float32),
                                      (di, st)))
    return {
        "in_proj": common.normal_init(ks[0], (d, 2 * di), d ** -0.5, dtype),
        "conv_w": common.normal_init(ks[1], (cfg.d_conv, di), cfg.d_conv ** -0.5, dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": common.normal_init(ks[2], (di, dr + 2 * st), di ** -0.5, dtype),
        "dt_proj": common.normal_init(ks[3], (dr, di), dr ** -0.5, dtype),
        "dt_bias": jnp.full((di,), -4.6, dtype),  # softplus^-1(0.01)
        "A_log": a_init.astype(jnp.float32),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": common.normal_init(ks[4], (di, d), di ** -0.5, dtype),
    }


def _mamba1_ssm_inputs(params, xc, cfg: ModelConfig):
    """xc (B,S,di) -> (a (B,S,di,N), b (B,S,di,N), C (B,S,N), dx (B,S,di))."""
    dr, st = cfg.dt_rank, cfg.ssm_state
    proj = (xc @ params["x_proj"]).astype(jnp.float32)
    dt_r, B_, C_ = jnp.split(proj, [dr, dr + st], axis=-1)
    dt = jax.nn.softplus(dt_r @ params["dt_proj"].astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))  # (B,S,di)
    A = -jnp.exp(params["A_log"])                                  # (di,N)
    a = jnp.exp(dt[..., None] * A)                                 # (B,S,di,N)
    xf = xc.astype(jnp.float32)
    b = (dt * xf)[..., None] * B_[..., None, :]                    # (B,S,di,N)
    return a, b, C_, xf


def mamba1_block(params, x, cfg: ModelConfig, ssm_state=None, conv_state=None):
    """Full-sequence Mamba-1 mixer.  x: (B,S,D) -> (y, (ssm, conv) states).

    The O(S·di·N) a/b/h tensors live only inside the chunk scan body
    (see ``chunked_ssm``); HBM sees (B,S,di)-sized tensors.
    """
    di = cfg.d_inner
    xz = x @ params["in_proj"]
    x_in, z = jnp.split(xz, 2, axis=-1)
    xc_pre = causal_conv1d(x_in, params["conv_w"], params["conv_b"])
    xc = jax.nn.silu(xc_pre.astype(jnp.float32)).astype(x.dtype)
    B = x.shape[0]
    h0 = jnp.zeros((B, di, cfg.ssm_state), jnp.float32) if ssm_state is None \
        else ssm_state

    if cfg.ssm_kernel:
        # fused Pallas selective scan (forward/serving path): h stays in
        # VMEM for the whole sequence, HBM sees only (B,S,di) tensors.
        from repro.kernels import ops as kops
        dr, st = cfg.dt_rank, cfg.ssm_state
        proj = (xc @ params["x_proj"]).astype(jnp.float32)
        dt_r, B_, C_ = jnp.split(proj, [dr, dr + st], axis=-1)
        dt = jax.nn.softplus(dt_r @ params["dt_proj"].astype(jnp.float32)
                             + params["dt_bias"].astype(jnp.float32))
        y, h_last = kops.selective_scan(
            xc.astype(jnp.float32), dt, params["A_log"], B_, C_,
            params["D"], h0=h0)      # D-skip applied inside the kernel
    else:
        def ab_fn(xc_c):
            a, b, _, _ = _mamba1_ssm_inputs(params, xc_c, cfg)
            return a, b

        def y_fn(h_all, xc_c):
            _, _, C_, xf = _mamba1_ssm_inputs(params, xc_c, cfg)
            return jnp.einsum("bsdn,bsn->bsd", h_all, C_) + params["D"] * xf

        y, h_last = chunked_ssm(ab_fn, y_fn, xc, h0, cfg.ssm_chunk)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    new_conv = x_in[:, -(cfg.d_conv - 1):, :]
    return y @ params["out_proj"], (h_last, new_conv)


def mamba1_decode_step(params, x, ssm_state, conv_state, cfg: ModelConfig):
    """x: (B,1,D); ssm_state (B,di,N) f32; conv_state (B,Kw-1,di)."""
    xz = x @ params["in_proj"]
    x_in, z = jnp.split(xz, 2, axis=-1)
    xc_pre, new_conv = conv_step(conv_state, x_in, params["conv_w"],
                                 params["conv_b"])
    xc = jax.nn.silu(xc_pre.astype(jnp.float32)).astype(x.dtype)
    a, b, C_, xf = _mamba1_ssm_inputs(params, xc, cfg)
    h = a[:, 0] * ssm_state + b[:, 0]                            # (B,di,N)
    y = jnp.einsum("bdn,bn->bd", h, C_[:, 0]) + params["D"] * xf[:, 0]
    y = (y[:, None] * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    return y @ params["out_proj"], h, new_conv


# ---------------------------------------------------------------------------
# Mamba-2 / SSD (zamba2)
# ---------------------------------------------------------------------------

def init_mamba2_params(rng, cfg: ModelConfig, dtype):
    d, di, st = cfg.d_model, cfg.d_inner, cfg.ssm_state
    nh = cfg.ssm_heads
    ks = jax.random.split(rng, 4)
    proj_out = 2 * di + 2 * st + nh
    return {
        "in_proj": common.normal_init(ks[0], (d, proj_out), d ** -0.5, dtype),
        "conv_w": common.normal_init(ks[1], (cfg.d_conv, di), cfg.d_conv ** -0.5, dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "dt_bias": jnp.full((nh,), -4.6, jnp.float32),
        "A_log": jnp.zeros((nh,), jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "out_proj": common.normal_init(ks[2], (di, d), di ** -0.5, dtype),
    }


def mamba2_block(params, x, cfg: ModelConfig, ssm_state=None, conv_state=None):
    """x: (B,S,D) -> (y, (ssm (B,nh,p,N), conv (B,Kw-1,di)))."""
    di, st, nh, p = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    Bsz, S = x.shape[0], x.shape[1]
    proj = x @ params["in_proj"]
    xz, rest = jnp.split(proj, [2 * di], axis=-1)
    x_in, z = jnp.split(xz, 2, axis=-1)
    B_, C_, dt_raw = jnp.split(rest, [st, 2 * st], axis=-1)
    xc_pre = causal_conv1d(x_in, params["conv_w"], params["conv_b"])
    xc = jax.nn.silu(xc_pre.astype(jnp.float32)).astype(x.dtype)

    A = -jnp.exp(params["A_log"])
    h0 = jnp.zeros((Bsz, nh, p, st), jnp.float32) if ssm_state is None \
        else ssm_state

    def ab_fn(inp):
        xc_c, B_c, _, dt_raw_c = inp
        dt = jax.nn.softplus(dt_raw_c.astype(jnp.float32) + params["dt_bias"])
        a = jnp.exp(dt * A)[..., None, None]                     # (B,c,nh,1,1)
        xh = xc_c.astype(jnp.float32).reshape(xc_c.shape[:2] + (nh, p))
        Bf = B_c.astype(jnp.float32)
        b = (dt[..., None] * xh)[..., None] * Bf[:, :, None, None, :]
        return a, b                                              # (B,c,nh,p,N)

    def y_fn(h_all, inp):
        xc_c, _, C_c, _ = inp
        xh = xc_c.astype(jnp.float32).reshape(xc_c.shape[:2] + (nh, p))
        yc = jnp.einsum("bshpn,bsn->bshp", h_all, C_c.astype(jnp.float32))
        return yc + params["D"][:, None] * xh

    y, h_last = chunked_ssm(ab_fn, y_fn, (xc, B_, C_, dt_raw), h0,
                            cfg.ssm_chunk)
    y = y.reshape(Bsz, S, di)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    new_conv = x_in[:, -(cfg.d_conv - 1):, :]
    return y @ params["out_proj"], (h_last, new_conv)


def mamba2_decode_step(params, x, ssm_state, conv_state, cfg: ModelConfig):
    di, st, nh, p = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    Bsz = x.shape[0]
    proj = x @ params["in_proj"]
    xz, rest = jnp.split(proj, [2 * di], axis=-1)
    x_in, z = jnp.split(xz, 2, axis=-1)
    B_, C_, dt_raw = jnp.split(rest, [st, 2 * st], axis=-1)
    xc_pre, new_conv = conv_step(conv_state, x_in, params["conv_w"],
                                 params["conv_b"])
    xc = jax.nn.silu(xc_pre.astype(jnp.float32)).astype(x.dtype)

    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    a = jnp.exp(dt * A)[..., None, None]                         # (B,nh,1,1)
    xh = xc.astype(jnp.float32).reshape(Bsz, nh, p)
    Bf = B_[:, 0].astype(jnp.float32)
    b = (dt[..., None] * xh)[..., None] * Bf[:, None, None, :]   # (B,nh,p,N)
    h = a * ssm_state + b
    y = jnp.einsum("bhpn,bn->bhp", h, C_[:, 0].astype(jnp.float32))
    y = y + params["D"][:, None] * xh
    y = y.reshape(Bsz, 1, di)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    return y @ params["out_proj"], h, new_conv
