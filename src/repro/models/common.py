"""Shared model building blocks: norms, RoPE, initialisers, dtype policy."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def normal_init(rng: jax.Array, shape, std: float, dtype) -> jax.Array:
    return (jax.random.normal(rng, shape, jnp.float32) * std).astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    head_dim = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(head_dim, theta))          # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., s, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]                     # (..., s, 1, hd/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Masks
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def attention_mask(q_pos: jax.Array, k_pos: jax.Array, *, window,
                   prefix_len: int = 0) -> jax.Array:
    """Boolean (..., q, k) mask of *allowed* attention.

    causal with optional sliding ``window`` (q - k < window); positions
    ``< prefix_len`` attend bidirectionally among themselves (prefix-LM,
    used by the VLM config).  ``window`` may be a traced scalar (per-layer
    local/global selection) — pass a huge value for full attention.
    """
    q = q_pos[..., :, None]
    k = k_pos[..., None, :]
    causal = k <= q
    windowed = (q - k) < window
    allowed = causal & windowed
    if prefix_len > 0:
        in_prefix = (q < prefix_len) & (k < prefix_len)
        allowed = allowed | in_prefix
    return allowed


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: jax.Array | None = None) -> jax.Array:
    """Mean token NLL.  logits (..., V) any float dtype; labels int."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def chunked_cross_entropy(x: jax.Array, lm_head: jax.Array, labels: jax.Array,
                          mask: jax.Array | None = None,
                          chunk: int = 2048) -> jax.Array:
    """CE that never materialises the full (tokens, V) logits tensor.

    Scans over sequence chunks: per chunk computes logits -> (logz, gold)
    and discards them.  ~V/chunk x less live memory for the loss; used as a
    beyond-paper memory optimisation for the 128k-262k vocab archs.

    x: (B, S, D); lm_head: (D, V); labels: (B, S).
    """
    b, s, d = x.shape
    n = max(1, s // chunk)
    while s % n != 0:
        n -= 1
    cs = s // n
    xs = x.reshape(b, n, cs, d).swapaxes(0, 1)            # (n, B, cs, D)
    ls = labels.reshape(b, n, cs).swapaxes(0, 1)
    ms = (mask.reshape(b, n, cs).swapaxes(0, 1).astype(jnp.float32)
          if mask is not None else jnp.ones((n, b, cs), jnp.float32))

    def body(carry, inp):
        tot, cnt = carry
        xc, lc, mc = inp
        logits = (xc @ lm_head).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        nll = (logz - gold) * mc
        return (tot + jnp.sum(nll), cnt + jnp.sum(mc)), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32),
                                        jnp.zeros((), jnp.float32)),
                                 (xs, ls, ms))
    return tot / jnp.maximum(cnt, 1.0)
