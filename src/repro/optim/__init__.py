from repro.optim.optimizers import (OptState, adamw, init_opt_state, sgd,
                                    sgd_momentum)
from repro.optim.schedules import constant, exp_decay, warmup_cosine
