"""Minimal functional optimizers (no optax dependency offline).

Used by the centralized baselines and the non-DFL training path; the DFL
inner loop implements its own update rules (Eq. 6) in ``core/admm.py``.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


class OptState(NamedTuple):
    mu: PyTree          # first moment / momentum
    nu: PyTree          # second moment (adamw only)
    count: jax.Array


def init_opt_state(params: PyTree) -> OptState:
    zeros = jax.tree.map(lambda x: jnp.zeros_like(x, dtype=jnp.float32), params)
    return OptState(mu=zeros, nu=zeros, count=jnp.zeros((), jnp.int32))


def sgd(params, grads, state: OptState, *, lr, weight_decay=0.0):
    if weight_decay:
        grads = jax.tree.map(lambda g, p: g + weight_decay * p, grads, params)
    new = jax.tree.map(lambda p, g: (p - lr * g.astype(p.dtype)), params, grads)
    return new, state._replace(count=state.count + 1)


def sgd_momentum(params, grads, state: OptState, *, lr, momentum=0.9,
                 weight_decay=0.0):
    if weight_decay:
        grads = jax.tree.map(lambda g, p: g + weight_decay * p, grads, params)
    mu = jax.tree.map(lambda m, g: momentum * m + g.astype(jnp.float32),
                      state.mu, grads)
    new = jax.tree.map(lambda p, m: p - lr * m.astype(p.dtype), params, mu)
    return new, OptState(mu=mu, nu=state.nu, count=state.count + 1)


def adamw(params, grads, state: OptState, *, lr, b1=0.9, b2=0.95, eps=1e-8,
          weight_decay=0.0):
    cnt = state.count + 1
    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                      state.mu, grads)
    nu = jax.tree.map(
        lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
        state.nu, grads)
    c1 = 1 - b1 ** cnt.astype(jnp.float32)
    c2 = 1 - b2 ** cnt.astype(jnp.float32)

    def upd(p, m, v):
        step = (m / c1) / (jnp.sqrt(v / c2) + eps)
        if weight_decay:
            step = step + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype)

    new = jax.tree.map(upd, params, mu, nu)
    return new, OptState(mu=mu, nu=nu, count=cnt)
