"""Learning-rate schedules.  The paper uses 0.1 * 0.998^round."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr):
    return lambda step: jnp.asarray(lr, jnp.float32)


def exp_decay(lr0, decay=0.998):
    return lambda step: lr0 * decay ** jnp.asarray(step, jnp.float32)


def warmup_cosine(lr0, warmup, total):
    import jax.numpy as jnp

    def f(step):
        step = jnp.asarray(step, jnp.float32)
        warm = lr0 * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = 0.5 * lr0 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)

    return f
