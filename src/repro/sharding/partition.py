"""Parameter / batch / cache PartitionSpec rules per architecture & mode.

All distribution is GSPMD-style: we annotate inputs/outputs of the jitted
step functions and let XLA propagate.  The client (DFL) axis is the
leading axis of every state leaf and maps to ``parallel.client_axis``
("data" on the single-pod mesh; "pod" is the giant-model variant).

Rules are name+rank based over the pytree paths produced by
``models.model.init_params``.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ParallelConfig

PyTree = Any


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


def base_param_spec(path: str, ndim: int, cfg: ModelConfig,
                    tensor: str = "model", fsdp: str = "") -> P:
    """Spec for one UNSTACKED-client leaf (leading L axis for layers/*)."""
    name = path.split("/")[-1]
    in_layers = path.startswith("layers/")
    lead = (None,) if in_layers else ()     # the scanned L axis

    def spec(*rest):
        return P(*(lead + rest))

    # --- embeddings / head ---------------------------------------------
    if name == "embed":
        return P(tensor, fsdp or None)
    if name == "lm_head":
        return P(fsdp or None, tensor)
    if name in ("final_norm",):
        return P(None)

    # --- attention -------------------------------------------------------
    if name in ("wq", "wk", "wv"):
        return spec(fsdp or None, tensor)
    if name == "wo":
        return spec(tensor, fsdp or None)
    if name in ("ln1", "ln2", "ln"):
        return spec(None)

    # --- dense mlp --------------------------------------------------------
    if name in ("w_gate", "w_up", "w_down") and ndim - len(lead) == 2:
        if name == "w_down":
            return spec(tensor, fsdp or None)
        return spec(fsdp or None, tensor)

    # --- moe experts (E, d, ff) ------------------------------------------
    if name == "router":
        return spec(fsdp or None, None)
    if name in ("w_gate", "w_up", "w_down") and ndim - len(lead) == 3:
        if cfg.expert_sharding == "expert":
            return spec(tensor, fsdp or None, None)
        if name == "w_down":
            return spec(None, tensor, fsdp or None)
        return spec(None, fsdp or None, tensor)

    # --- mamba ------------------------------------------------------------
    if name == "in_proj":
        return spec(fsdp or None, tensor)
    if name == "conv_w":
        return spec(None, tensor)
    if name in ("conv_b", "dt_bias", "D"):
        return spec(tensor)
    if name == "x_proj":
        return spec(tensor, None)
    if name == "dt_proj":
        return spec(None, tensor)
    if name == "A_log":
        return spec(tensor, None) if ndim - len(lead) == 2 else spec(None)
    if name == "out_proj":
        return spec(tensor, fsdp or None)

    # fallback: replicate
    return P(*([None] * ndim))


def param_specs(shapes: PyTree, cfg: ModelConfig, par: ParallelConfig,
                *, stacked_client: bool = False) -> PyTree:
    """PartitionSpec pytree for a params tree.

    ``shapes`` is always the UNSTACKED single-model tree; with
    ``stacked_client=True`` the returned specs carry a leading client-axis
    entry (for the (m, ...) DFL state leaves).
    """
    def one(path, leaf):
        p = _path_str(path)
        spec = base_param_spec(p, leaf.ndim, cfg, tensor=par.tensor_axis,
                               fsdp=par.fsdp_axis)
        if stacked_client:
            spec = P(par.client_axis, *spec)
        return spec

    return jax.tree_util.tree_map_with_path(one, shapes)


def dfl_state_specs(param_tree: PyTree, cfg: ModelConfig,
                    par: ParallelConfig, algorithm: str = "dfedadmm",
                    dfl_cfg: Any = None) -> Any:
    """Specs for core.dfl.DFLState with stacked (m, ...) leaves.

    The leading (m,) axis is the *hot cohort* under cohort
    virtualization (``repro.core.cohort``): the gathered slots shard
    over ``par.client_axis`` exactly like a fully device-resident
    population, so the same specs serve both regimes.

    The solver-owned state slot (``DFLState.solver``) takes its structure
    from the algorithm's ``LocalSolver.state_specs`` — param-shaped
    buffers (duals, momentum) share the stacked param specs, and solvers
    without state contribute no specs at all.  Passing the run's
    ``dfl_cfg`` (a ``core.dfl.DFLConfig``) also lays out the
    communication slot (``DFLState.comm``): push-sum weights shard over
    the client axis, codec error-feedback residuals share the stacked
    param specs; without it ``comm`` is None (the stateless layout)."""
    from repro.core import solvers as solvers_lib
    from repro.core.dfl import DFLConfig, DFLState
    ps = param_specs(param_tree, cfg, par, stacked_client=True)
    solver = solvers_lib.make_solver(DFLConfig(algorithm=algorithm))
    comm = {}
    if dfl_cfg is not None:
        from repro.core import comm as comm_lib
        if dfl_cfg.transport == "pushsum":
            comm["ps_weight"] = P(par.client_axis)
        if comm_lib.make_codec(dfl_cfg).stateful:
            comm["residual"] = ps
    if solver.tracks:
        # the gossip-carried tracking buffer (comm.init_comm_state
        # allocates it for any transport/codec, so the spec exists even
        # without a dfl_cfg): param-shaped, stacked over the client axis
        comm["track"] = ps
    comm = comm or None
    return DFLState(params=ps,
                    solver=solver.state_specs(ps, par.client_axis),
                    rng=P(par.client_axis, None),
                    round=P(),
                    comm=comm)


def train_batch_specs(batch_shapes: PyTree, par: ParallelConfig) -> PyTree:
    """(m, K, b_local, ...) leaves: client axis + batch axes."""
    baxes = tuple(a for a in par.batch_axes if a != par.client_axis)
    batch_axis = baxes[0] if baxes else None

    def one(leaf):
        rest = (None,) * (leaf.ndim - 3)
        return P(par.client_axis, None, batch_axis, *rest)

    return jax.tree.map(one, batch_shapes)


def prefill_batch_specs(batch_shapes: PyTree, par: ParallelConfig,
                        multi_pod: bool) -> PyTree:
    axes = ("pod", "data") if multi_pod else ("data",)

    def one(leaf):
        return P(axes, *([None] * (leaf.ndim - 1)))

    return jax.tree.map(one, batch_shapes)


def decode_specs(specs_tree: PyTree, cfg: ModelConfig, par: ParallelConfig,
                 multi_pod: bool, *, long_context: bool = False,
                 kv_shard: str = "") -> PyTree:
    """Specs for {"token": ..., "cache": {...}} decode inputs.

    Normal decode: batch axis of token & cache sharded over data(+pod).
    Long-context (B=1): KV cache sequence axis sharded over "data"
    (flash-decode shards); SSM state replicated batch-wise.

    ``kv_shard``: additionally shard the KV cache over the tensor axis —
    "hd" shards the head_dim axis (works for any kv-head count),
    "heads" shards the kv-head axis (needs kv_heads % tp == 0).  This is
    the §Perf lever that keeps the cache aligned with the TP-sharded
    q/k/v projections so GSPMD never reshards the cache inside the
    per-layer scan.
    """
    batch_axes = ("pod", "data") if multi_pod else ("data",)
    tensor = par.tensor_axis

    def token_spec(leaf):
        if long_context:
            return P(*([None] * leaf.ndim))
        return P(batch_axes, *([None] * (leaf.ndim - 1)))

    out = {"token": jax.tree.map(token_spec, specs_tree["token"])}

    def cache_spec(path, leaf):
        name = _path_str(path)
        if name == "pos":
            return P()
        if name in ("k", "v"):
            head_ax = tensor if kv_shard == "heads" else None
            hd_ax = tensor if kv_shard == "hd" else None
            seq_ax = tensor if kv_shard == "seq" else None
            if long_context:
                return P(None, None, "data", head_ax, hd_ax)
            return P(None, batch_axes, seq_ax, head_ax, hd_ax)
        if name in ("ssm", "conv"):
            if long_context:
                return P(*([None] * leaf.ndim))
            return P(None, batch_axes, *([None] * (leaf.ndim - 2)))
        return P(*([None] * leaf.ndim))

    out["cache"] = jax.tree_util.tree_map_with_path(
        cache_spec, specs_tree["cache"])
    return out


def to_shardings(spec_tree: PyTree, mesh) -> PyTree:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
