from repro.sharding.partition import (base_param_spec, decode_specs,
                                      dfl_state_specs, param_specs,
                                      prefill_batch_specs, to_shardings,
                                      train_batch_specs)
