"""Network cost model: per-link bandwidth/latency -> simulated wall-clock.

The repo measured communication in *rounds* and *bytes*; heterogeneous
real networks cost *time* (the communication/computing cost-balancing
analysis of arXiv:2107.12048, and the asymmetric-link setting of
arXiv:2310.05093 whose directed topologies the push-sum transport
already supports).  This module is the fourth pluggable layer next to
transport / codec / solver: a declarative per-link cost model that
composes with every ``Transport.prepare`` plan.

``NetworkModel`` holds two (m, m) host-side numpy matrices — like the
gossip matrices, they are tiny and never enter jit:

* ``bandwidth[i, j]`` — bytes/second of the link j -> i (the same
  receive convention as the gossip matrices: row i lists who i hears);
* ``latency[i, j]``   — seconds of fixed per-message latency on j -> i.

Given this round's effective communication graph (the matrix behind the
transport's plan — symmetric, masked, or column-stochastic push-sum
alike: any nonzero off-diagonal ``w[i, j]`` means a message j -> i) and
the codec's modeled message size (``MessageCodec.bytes_per_client``),
the model yields per-client transfer times and the critical-path round
time recorded by ``simulate`` as ``history["sim_time"]``::

    link_seconds(i, j) = jitter_t[i, j] * (latency[i, j] + nbytes / bandwidth[i, j])
    transfer_i         = max over in-neighbours j of link_seconds(i, j)
    sim_time           = K * compute_s + max over active i of transfer_i

``jitter_t`` is a per-round, per-link multiplicative lognormal draw with
mean 1, regenerated from ``(seed, t)`` exactly like the participation
masks — schedules are reproducible without carrying RNG state.

The model also closes the loop back into the scenario engine:
``ParticipationSpec(mode="deadline", deadline=...)`` masks the clients
whose modeled transfer misses the round deadline (see
``participation.round_participation``), so slow links *cause* partial
participation instead of it being sampled i.i.d.

Presets (``make_network``):

* ``uniform``       — every link identical; the degenerate control.
* ``lognormal``     — per-link bandwidths/latencies drawn lognormal at
  construction: heavy-tailed heterogeneity, a few very slow links.
* ``hub-and-spoke`` — client 0 is a datacenter hub: hub links are fast,
  spoke<->spoke links are slow (routed via the hub).
* ``wan-lan``       — clients in LAN sites of 4: intra-site links are
  fast, cross-site WAN links are slow and high-latency.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core._registry import FactoryRegistry

NETWORKS = ("uniform", "lognormal", "hub-and-spoke", "wan-lan")

# reference link speeds (bytes/second) and latencies (seconds)
_FAST_BW, _FAST_LAT = 125e6, 1e-3      # ~1 Gb/s LAN / datacenter link
_BASE_BW, _BASE_LAT = 10e6, 5e-3       # ~80 Mb/s commodity uplink
_SLOW_BW, _SLOW_LAT = 6.4e4, 20e-3     # ~512 kb/s constrained edge uplink


@dataclasses.dataclass(frozen=True)
class NetworkModel:
    """Per-link bandwidth/latency cost model for one federation of m clients.

    Attributes:
      name:      preset name (or "custom" for hand-built models).
      bandwidth: (m, m) float64, bytes/second of link j -> i.
      latency:   (m, m) float64, seconds of fixed latency on j -> i.
      jitter:    sigma of the mean-1 lognormal per-round multiplicative
                 jitter applied per link (0 disables jitter).
      seed:      base seed; round ``t`` jitter draws from
                 ``default_rng((seed, t))``.
      compute_s: modeled seconds of local compute per local iteration
                 (the "local compute estimate" term of ``sim_time``).
    """

    name: str
    bandwidth: np.ndarray
    latency: np.ndarray
    jitter: float = 0.0
    seed: int = 0
    compute_s: float = 0.002

    def __post_init__(self):
        bw = np.asarray(self.bandwidth, dtype=np.float64)
        lat = np.asarray(self.latency, dtype=np.float64)
        if bw.ndim != 2 or bw.shape[0] != bw.shape[1]:
            raise ValueError(f"bandwidth must be (m, m), got {bw.shape}")
        if lat.shape != bw.shape:
            raise ValueError(
                f"latency shape {lat.shape} != bandwidth shape {bw.shape}")
        if np.any(bw <= 0):
            raise ValueError("link bandwidths must be positive")
        if np.any(lat < 0):
            raise ValueError("link latencies must be non-negative")
        if self.jitter < 0:
            raise ValueError(f"jitter must be >= 0, got {self.jitter}")
        if self.compute_s < 0:
            raise ValueError(f"compute_s must be >= 0, got {self.compute_s}")
        object.__setattr__(self, "bandwidth", bw)
        object.__setattr__(self, "latency", lat)

    @property
    def m(self) -> int:
        return self.bandwidth.shape[0]

    def _jitter_factor(self, t: int) -> np.ndarray:
        """(m, m) mean-1 multiplicative jitter for round ``t`` (all-ones
        when jitter is disabled); deterministic in ``(seed, t)``."""
        if self.jitter == 0.0:
            return np.ones((self.m, self.m))
        rng = np.random.default_rng((self.seed, t))
        return rng.lognormal(mean=-0.5 * self.jitter ** 2,
                             sigma=self.jitter, size=(self.m, self.m))

    def link_seconds(self, nbytes: int, t: int) -> np.ndarray:
        """(m, m) modeled seconds to move one ``nbytes`` message over each
        link j -> i in round ``t`` (latency + serialization, jittered)."""
        base = self.latency + float(nbytes) / self.bandwidth
        return base * self._jitter_factor(t)

    def transfer_times(self, w: np.ndarray, nbytes: int, t: int,
                       active: np.ndarray | None = None) -> np.ndarray:
        """Per-client receive-completion times under the round's graph.

        Args:
          w:      (m, m) effective gossip matrix — any transport's plan
                  matrix (symmetric, masked, or column-stochastic
                  push-sum): ``w[i, j] != 0`` off the diagonal means a
                  message j -> i this round.
          nbytes: modeled message size (``MessageCodec.bytes_per_client``).
          t:      round index (selects the jitter draw).
          active: optional (m,) bool mask; only links between active
                  pairs count, and inactive clients wait for nothing.

        Returns (m,) float64: for each client, the slowest of its
        in-neighbour links (0.0 for clients with no in-neighbours).
        """
        w = np.asarray(w, dtype=np.float64)
        if w.shape != (self.m, self.m):
            raise ValueError(
                f"gossip matrix shape {w.shape} does not match the "
                f"network model's m={self.m}")
        edges = (w != 0.0)
        np.fill_diagonal(edges, False)
        if active is not None:
            active = np.asarray(active, dtype=bool)
            edges &= np.outer(active, active)
        times = np.where(edges, self.link_seconds(nbytes, t), 0.0)
        return times.max(axis=1)

    def round_time(self, w: np.ndarray, nbytes: int, t: int, K: int,
                   active: np.ndarray | None = None) -> float:
        """Critical-path wall-clock of one round: ``K`` local iterations
        of modeled compute plus the slowest active in-neighbour link
        (every client computes in parallel; the round ends when the last
        active client has heard all its active in-neighbours)."""
        transfer = self.transfer_times(w, nbytes, t, active=active)
        if active is not None:
            transfer = transfer[np.asarray(active, dtype=bool)]
        slowest = float(transfer.max()) if transfer.size else 0.0
        return K * self.compute_s + slowest

    def tiered_round_time(self, tiers, nbytes: int, t: int, K: int,
                          active: np.ndarray | None = None) -> float:
        """Critical-path wall-clock of one *multi-tier* round: ``K``
        iterations of modeled compute plus the per-tier critical paths
        summed, because the tiers run sequentially (the hierarchical
        transport gossips inside each cluster before the cluster heads
        exchange across clusters).  Each tier is priced exactly like a
        flat round's graph."""
        total = K * self.compute_s
        for w in tiers:
            transfer = self.transfer_times(w, nbytes, t, active=active)
            if active is not None:
                transfer = transfer[np.asarray(active, dtype=bool)]
            total += float(transfer.max()) if transfer.size else 0.0
        return total

    def deadline_round_time(self, transfer: np.ndarray, active: np.ndarray,
                            K: int) -> float:
        """Wall-clock of one deadline-mode round: ``K`` iterations of
        modeled compute plus the slowest *realized* receive among the
        clients kept in the round.

        ``transfer`` is the pre-mask per-client transfer vector the
        deadline decision itself consumed (``transfer_times`` over the
        full round graph): every included client physically waited for
        all its in-links before the deadline was judged, so a client the
        ``min_active`` floor forces in past the deadline prices *its*
        wait — not the post-mask subgraph's (the masked recompute drops
        the forced client's slow in-links along with the masked senders)
        and not the pre-mask critical path over clients that sat out.
        """
        transfer = np.asarray(transfer, dtype=np.float64)
        waited = transfer[np.asarray(active, dtype=bool)]
        slowest = float(waited.max()) if waited.size else 0.0
        return K * self.compute_s + slowest

    def uplink_seconds(self, nbytes: int, t: int) -> np.ndarray:
        """(m,) per-client worst outgoing-link time for one ``nbytes``
        message — the server-upload model used by ``simulate_cfl``
        (client j's upload is bounded by its slowest out-link)."""
        times = self.link_seconds(nbytes, t)
        mask = ~np.eye(self.m, dtype=bool)
        return np.where(mask, times, 0.0).max(axis=0)


def _lognormal_matrix(rng, center, sigma, m):
    return center * rng.lognormal(mean=-0.5 * sigma ** 2, sigma=sigma,
                                  size=(m, m))


# user-registered preset builders (register_network); the builtin names
# in ``NETWORKS`` are resolved by the if-chain in make_network
_PRESET_REGISTRY = FactoryRegistry("network preset", NETWORKS)


def register_network(name: str, builder, overwrite: bool = False) -> None:
    """Register ``builder(m, seed) -> NetworkModel`` under ``name``.

    Mirrors ``solvers.register_solver``: a registered preset is
    selectable via ``DFLConfig(network=name)`` (config validation
    resolves through :func:`network_names`).  The train CLI's
    ``--network`` choices are fixed to the builtin presets — a CLI
    process never imports user registration code.
    """
    _PRESET_REGISTRY.register(name, builder, overwrite)


def network_names() -> tuple[str, ...]:
    """All selectable preset names: builtins plus registered ones."""
    return _PRESET_REGISTRY.names()


def make_network(preset, m: int, *, seed: int = 0, jitter: float = 0.05,
                 compute_s: float = 0.002, site: int = 4,
                 hubs: int = 0) -> NetworkModel:
    """Build one of the ``NETWORKS`` presets for ``m`` clients.

    Args:
      preset:    preset name from ``NETWORKS``, or an existing
                 ``NetworkModel`` (returned unchanged after an m check —
                 lets config fields hold either form).
      m:         number of clients.
      seed:      seeds both the construction-time link draws and the
                 per-round jitter stream.
      jitter:    per-round lognormal jitter sigma (0 disables).
      compute_s: modeled seconds per local iteration.
      site:      LAN site size for the ``wan-lan`` preset.
      hubs:      cluster-aware ``hub-and-spoke``: with ``hubs > 1`` the
                 clients form ``hubs`` contiguous clusters
                 (``gossip.cluster_labels``), links inside a cluster and
                 between cluster heads are fast, everything crossing
                 clusters off the head backbone is slow.  The default 0
                 (and 1) keeps the classic single-hub star around
                 client 0.
    """
    if isinstance(preset, NetworkModel):
        if preset.m != m:
            raise ValueError(
                f"network model is sized for m={preset.m}, config has m={m}")
        return preset
    if preset in _PRESET_REGISTRY:
        model = _PRESET_REGISTRY.build(preset, m, seed)
        if model.m != m:
            raise ValueError(
                f"registered preset {preset!r} built a model for "
                f"m={model.m}, config has m={m}")
        return model
    rng = np.random.default_rng((seed, 0x4E7))   # construction-time stream
    if preset == "uniform":
        bw = np.full((m, m), _BASE_BW)
        lat = np.full((m, m), _BASE_LAT)
    elif preset == "lognormal":
        # heavy-tailed per-link heterogeneity: the slowest few links sit
        # orders of magnitude below the median — the straggler-link regime
        bw = _lognormal_matrix(rng, _BASE_BW, 2.0, m)
        lat = _lognormal_matrix(rng, _BASE_LAT, 0.5, m)
    elif preset == "hub-and-spoke":
        if hubs > 1:
            # cluster-aware: fast LAN inside each contiguous cluster plus
            # a fast backbone between the cluster heads — the exact edge
            # set the two-tier hier transport gossips over
            from repro.core.gossip import cluster_heads, cluster_labels
            labels = cluster_labels(m, hubs)
            is_head = np.zeros(m, dtype=bool)
            is_head[cluster_heads(labels)] = True
            fast = ((labels[:, None] == labels[None, :])
                    | np.outer(is_head, is_head))
        else:
            fast = np.zeros((m, m), dtype=bool)
            fast[0, :] = fast[:, 0] = True
        bw = np.where(fast, _FAST_BW, _SLOW_BW)
        lat = np.where(fast, _FAST_LAT, _SLOW_LAT)
    elif preset == "wan-lan":
        sites = np.arange(m) // max(site, 1)
        same = sites[:, None] == sites[None, :]
        bw = np.where(same, _FAST_BW, _SLOW_BW)
        lat = np.where(same, _FAST_LAT, _SLOW_LAT)
    else:
        raise ValueError(f"unknown network preset {preset!r}; expected "
                         f"one of {network_names()}")
    return NetworkModel(name=str(preset), bandwidth=bw, latency=lat,
                        jitter=jitter, seed=seed, compute_s=compute_s)
