"""Gossip mixing execution strategies.

Two executable forms of Alg. 1 line 19  ``x_i <- sum_l w_il z_l``:

* ``mix_dense``     — einsum against the full (m, m) matrix.  On a mesh with
  the client axis sharded this lowers to an all-gather of ``z`` along the
  client axis followed by a local contraction.  Works for *any* topology.

* ``mix_ppermute``  — neighbour-only exchange with
  ``jax.lax.ppermute`` (collective_permute) under ``shard_map``.  Valid for
  circulant topologies (ring / exp / full on a homogeneous client layout)
  where every client applies the same offset->weight pattern.  Collective
  bytes scale with the node degree instead of with m — this is the
  TPU-native form of the paper's sparse gossip and the main lever in the
  §Perf hillclimb.

Both preserve the client-mean for doubly-stochastic W (tested).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.gossip import GossipSpec

PyTree = Any


def mix_dense(w: jax.Array | np.ndarray, z: PyTree) -> PyTree:
    """x_i = sum_j w_ij z_j over the leading (client) axis of every leaf.

    The contraction runs in f32 and the result is cast back to the leaf
    dtype: casting W down to bf16 instead would de-normalize the rows
    (a bf16 gossip matrix is no longer doubly stochastic to machine
    precision), so the client-mean would drift every round.
    """
    w = jnp.asarray(w)

    def leaf(arr):
        out = jnp.einsum("ij,j...->i...", w.astype(jnp.float32),
                         arr.astype(jnp.float32))
        return out.astype(arr.dtype)

    return jax.tree.map(leaf, z)


def _circulant_pattern(spec: GossipSpec) -> list[tuple[int, float]]:
    """(offset, weight) pairs shared by all clients, including self (0)."""
    if not spec.is_circulant():
        raise ValueError(
            f"ppermute mixing requires a circulant topology; {spec.topology!r} "
            "with these weights is not shift-invariant")
    row0 = spec.matrix[0]
    return [(int(j), float(row0[j])) for j in np.flatnonzero(row0 > 0)]


def mix_ppermute_local(z_local: PyTree, spec: GossipSpec, axis_name: str) -> PyTree:
    """Per-shard mixing body (call under shard_map / with a bound axis).

    ``z_local`` leaves have a leading client axis of the *local* size
    (usually 1 when m == mesh axis size).  Each (offset, weight) pair turns
    into one collective_permute of the full message.
    """
    m = spec.m
    pattern = _circulant_pattern(spec)

    def leaf(arr):
        acc = None
        for off, wgt in pattern:
            if off == 0:
                contrib = arr * wgt
            else:
                # receive from client (i - off) mod m  ==  send i -> i + off
                perm = [(src, (src + off) % m) for src in range(m)]
                contrib = jax.lax.ppermute(arr, axis_name, perm) * wgt
            acc = contrib if acc is None else acc + contrib
        return acc

    return jax.tree.map(leaf, z_local)


def mix_ppermute(z: PyTree, spec: GossipSpec, mesh: jax.sharding.Mesh,
                 client_axis: str, inner_specs: PyTree | None = None) -> PyTree:
    """shard_map wrapper: leaves are stacked (m, ...) with the client axis
    sharded over ``client_axis``; mixing happens via collective_permute."""
    if inner_specs is None:
        pspec = jax.tree.map(lambda _: P(client_axis), z)
    else:
        pspec = inner_specs

    fn = functools.partial(mix_ppermute_local, spec=spec, axis_name=client_axis)
    return jax.shard_map(fn, mesh=mesh, in_specs=(pspec,), out_specs=pspec,
                         check_vma=False)(z)


def mix_ppermute_local_masked(z_local: PyTree, gates, self_w, spec: GossipSpec,
                              axis_name: str) -> PyTree:
    """Participation-gated per-shard mixing body.

    Realizes ``mask_and_renormalize(W, active) @ z`` on the ppermute path
    without ever materializing the (non-circulant) masked matrix: every
    permute still fires (fixed communication schedule, no shape change),
    but each received contribution is scaled by its per-client gate
    ``active[sender] * active[receiver]`` and the self-weight absorbs the
    lost mass — inactive clients end up with gate rows of zero and a self
    weight of exactly 1, holding their state bitwise.

    ``gates``: (local_m, n_off) f32, one column per non-zero offset of the
    circulant pattern, in ``_circulant_pattern`` order (offset 0 excluded).
    ``self_w``: (local_m,) f32.  Both are sharded along the client axis.
    """
    m = spec.m
    pattern = [(off, wgt) for off, wgt in _circulant_pattern(spec) if off != 0]

    def leaf(arr):
        extra = (1,) * (arr.ndim - 1)
        acc = arr * self_w.reshape((-1,) + extra)
        for col, (off, wgt) in enumerate(pattern):
            perm = [(src, (src + off) % m) for src in range(m)]
            gate = (wgt * gates[:, col]).reshape((-1,) + extra)
            acc = acc + jax.lax.ppermute(arr, axis_name, perm) * gate
        return acc

    return jax.tree.map(leaf, z_local)


def mix_ppermute_masked(z: PyTree, gates, self_w, spec: GossipSpec,
                        mesh: jax.sharding.Mesh, client_axis: str,
                        inner_specs: PyTree | None = None) -> PyTree:
    """shard_map wrapper for the participation-gated ppermute path."""
    if inner_specs is None:
        pspec = jax.tree.map(lambda _: P(client_axis), z)
    else:
        pspec = inner_specs

    fn = functools.partial(mix_ppermute_local_masked, spec=spec,
                           axis_name=client_axis)
    return jax.shard_map(
        fn, mesh=mesh,
        in_specs=(pspec, P(client_axis, None), P(client_axis)),
        out_specs=pspec, check_vma=False)(z, gates, self_w)


def ppermute_gates(spec: GossipSpec, active: np.ndarray
                   ) -> tuple[np.ndarray, np.ndarray]:
    """Host-side plan for ``mix_ppermute_masked``.

    Returns ``(gates (m, n_off) f32, self_w (m,) f32)`` such that the gated
    circulant exchange equals ``gossip.mask_and_renormalize(W, active)``:
    ``gates[i, col] = active[i - off_col] * active[i]`` and the self weight
    is ``1 - sum_col w_col * gates[i, col]`` (identically 1 for inactive i).
    """
    active = np.asarray(active, dtype=bool)
    pattern = [(off, wgt) for off, wgt in _circulant_pattern(spec) if off != 0]
    gates = np.stack([np.roll(active, off) & active for off, _ in pattern],
                     axis=1).astype(np.float64)
    wgts = np.array([wgt for _, wgt in pattern])
    self_w = 1.0 - gates @ wgts
    return gates.astype(np.float32), self_w.astype(np.float32)


def mix_pushsum_ppermute_local(z_local: PyTree, pi_local: jax.Array,
                               spec: GossipSpec, axis_name: str
                               ) -> tuple[PyTree, jax.Array]:
    """Per-shard push-sum body for *directed circulant* topologies.

    One round of push-sum on the collective_permute substrate: the
    biased messages ``pi_j * z_j`` ride one permute per nonzero offset
    of the column-stochastic circulant ``P``, and the (m,) push-sum
    weight scalar rides ONE extra permute chain over the same offsets —
    ``pi' = P @ pi`` without materializing ``P``.  De-biased parameters
    are the elementwise ratio, exactly like ``PushSumTransport.mix``.

    Directed offsets: ``P[i, j] = p0[(j - i) % m]``, so receiver ``i``
    hears sender ``i + off`` — each send goes ``src -> src - off``
    (mod m), the mirror of the symmetric path's ``src -> src + off``.
    """
    m = spec.m
    pattern = _circulant_pattern(spec)

    def shift(arr, off):
        if off == 0:
            return arr
        perm = [(src, (src - off) % m) for src in range(m)]
        return jax.lax.ppermute(arr, axis_name, perm)

    pi = pi_local.astype(jnp.float32)
    pi_new = sum(wgt * shift(pi, off) for off, wgt in pattern)

    def leaf(arr):
        extra = (1,) * (arr.ndim - 1)
        biased = arr.astype(jnp.float32) * pi.reshape((-1,) + extra)
        u = sum(wgt * shift(biased, off) for off, wgt in pattern)
        return (u / pi_new.reshape((-1,) + extra)).astype(arr.dtype)

    return jax.tree.map(leaf, z_local), pi_new


def mix_pushsum_ppermute(z: PyTree, pi: jax.Array, spec: GossipSpec,
                         mesh: jax.sharding.Mesh, client_axis: str,
                         inner_specs: PyTree | None = None
                         ) -> tuple[PyTree, jax.Array]:
    """shard_map wrapper for the push-sum ppermute path: leaves stacked
    (m, ...) and the weight vector (m,), both sharded over
    ``client_axis``."""
    if inner_specs is None:
        pspec = jax.tree.map(lambda _: P(client_axis), z)
    else:
        pspec = inner_specs

    fn = functools.partial(mix_pushsum_ppermute_local, spec=spec,
                           axis_name=client_axis)
    return jax.shard_map(
        fn, mesh=mesh, in_specs=(pspec, P(client_axis)),
        out_specs=(pspec, P(client_axis)), check_vma=False)(z, pi)


def mix(z: PyTree, spec: GossipSpec, *, strategy: str = "dense",
        mesh: jax.sharding.Mesh | None = None, client_axis: str = "data",
        axis_bound: bool = False) -> PyTree:
    """Dispatch helper.

    strategy:
      "dense"     -> einsum with W  (any topology)
      "ppermute"  -> neighbour collective_permute (circulant topologies);
                     requires ``mesh``+``client_axis`` unless ``axis_bound``
                     (already inside a shard_map with the axis in scope).
    """
    if strategy == "dense":
        return mix_dense(spec.matrix, z)
    if strategy == "ppermute":
        if axis_bound:
            return mix_ppermute_local(z, spec, client_axis)
        if mesh is None:
            raise ValueError("ppermute mixing needs a mesh (or axis_bound=True)")
        return mix_ppermute(z, spec, mesh, client_axis)
    raise ValueError(f"unknown mixing strategy {strategy!r}")
