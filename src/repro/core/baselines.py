"""Centralized-FL baselines the paper compares against: FedAvg, FedSAM,
and FedPD (the ADMM ancestor, Eqs. 3-5).

Decentralized baselines (D-PSGD, DFedAvg, DFedAvgM, DFedSAM) live in
``core/dfl.py`` since they share the gossip round structure.

These are intentionally simple single-device simulators (vmap over the
sampled cohort); they exist for the faithful-reproduction experiments.
The inner loops are NOT re-implemented here: ``client_update`` drives
the same ``LocalSolver`` objects (``core/solvers.py``) the decentralized
round uses — FedPD's ADMM step is ``ADMMSolver`` with the FedPD server
message (Eq. 5, new dual), FedAvg/FedSAM are the stateless
``SGDSolver`` — so an algorithm ported to the solver registry runs on
both substrates for free.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core import comm as comm_lib, sam, solvers as solvers_lib

PyTree = Any


@dataclasses.dataclass(frozen=True)
class CFLConfig:
    algorithm: str = "fedavg"     # any solver registered under the "cfl" scope
    m: int = 100                  # total clients
    participation: float = 0.1    # cohort fraction per round
    K: int = 5
    lr: float = 0.1
    lr_decay: float = 0.998
    global_lr: float = 1.0
    rho: float = 0.1              # fedsam
    lam: float = 0.1              # fedpd
    weight_decay: float = 5e-4
    network: Any = None           # repro.core.network preset name /
                                  # NetworkModel; models the cohort's
                                  # upload wall-clock (history["sim_time"])

    def __post_init__(self):
        if self.algorithm not in solvers_lib.solver_names("cfl"):
            raise ValueError(
                f"unknown algorithm {self.algorithm!r}; registered CFL "
                f"solvers: {solvers_lib.solver_names('cfl')}")
        from repro.core.network import NetworkModel, network_names
        if self.network is not None and not isinstance(
                self.network, NetworkModel) and \
                self.network not in network_names():
            raise ValueError(
                f"unknown network preset {self.network!r}; expected a "
                f"NetworkModel or one of {network_names()}")

    @property
    def cohort(self) -> int:
        return max(1, int(round(self.m * self.participation)))


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class CFLState:
    global_params: PyTree
    solver: PyTree                # (m, ...) solver-owned per-client state
                                  # ({"dual": ...} for fedpd, None otherwise)
    rng: jax.Array
    round: jax.Array


def init_cfl_state(params: PyTree, cfg: CFLConfig, seed: int = 0) -> CFLState:
    solver = solvers_lib.make_solver(cfg)
    stacked = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (cfg.m,) + x.shape), params)
    return CFLState(global_params=params,
                    solver=solver.init_state(cfg, stacked),
                    rng=jax.random.PRNGKey(seed),
                    round=jnp.zeros((), jnp.int32))


def make_cfl_round(loss_fn: Callable[[PyTree, Any, jax.Array], jax.Array],
                   cfg: CFLConfig):
    """Build ``round_fn(state, cohort_ids, batches) -> (state, metrics)``.

    ``cohort_ids``: (cohort,) int32 client indices sampled by the caller.
    ``batches`` leaves: (cohort, K, ...).
    """
    solver = solvers_lib.make_solver(cfg)
    loss_and_grad = sam.sam_value_and_grad(loss_fn, solver.sam_rho)

    def client_update(x0, sstate_i, batches_k, rng, lr_t):
        def body(carry, batch):
            params, st, rng_ = carry
            rng_, sub = jax.random.split(rng_)
            l, g = loss_and_grad(params, batch, sub)
            params, st = solver.step(params, g, st, x0, lr_t)
            return (params, st, rng_), l

        (xk, st_K, _), losses = jax.lax.scan(
            body, (x0, sstate_i, rng), batches_k)
        new_st, msg = solver.finalize(xk, st_K, x0, lr_t)
        return msg, new_st, jnp.mean(losses)

    def round_fn(state: CFLState, cohort_ids: jax.Array, batches: PyTree):
        lr_t = cfg.lr * (cfg.lr_decay ** state.round.astype(jnp.float32))
        rng, *subs = jax.random.split(state.rng, cfg.cohort + 1)
        subs = jnp.stack(subs)
        cohort_state = jax.tree.map(lambda d: d[cohort_ids], state.solver)

        msgs, new_states, losses = jax.vmap(
            client_update, in_axes=(None, 0, 0, 0, None)
        )(state.global_params, cohort_state, batches, subs, lr_t)

        mean_msg = jax.tree.map(lambda z: jnp.mean(z, axis=0), msgs)
        if solver.is_admm:
            # FedPD: the mean client message IS the next global model
            new_global = mean_msg
        else:
            # server step: x0 + global_lr * (mean(x_i) - x0)
            new_global = jax.tree.map(
                lambda x0, z: x0 + cfg.global_lr * (z - x0),
                state.global_params, mean_msg)

        new_solver = jax.tree.map(lambda d, nd: d.at[cohort_ids].set(nd),
                                  state.solver, new_states)
        new_state = CFLState(global_params=new_global, solver=new_solver,
                             rng=rng, round=state.round + 1)
        return new_state, {"loss": jnp.mean(losses), "lr": lr_t}

    return round_fn


def simulate_cfl(loss_fn, eval_fn, params: PyTree, cfg: CFLConfig,
                 sample_batches: Callable[[int, Any], PyTree], rounds: int,
                 seed: int = 0, eval_every: int = 10):
    """sample_batches(t, cohort_ids) -> leaves (cohort, K, ...).

    The history shares the DFL ``simulate`` schema (``round``, ``loss``,
    ``lr``, ``wire_bytes``, ``eval``) so downstream table renderers
    (``experiments/update_tables.py``) handle DFL and CFL runs
    uniformly; ``wire_bytes`` models the uplink as cohort clients each
    sending one full-precision parameter message per round.  With
    ``cfg.network`` set, ``history["sim_time"]`` records each round's
    modeled wall-clock: K local compute steps plus the slowest cohort
    member's upload (``NetworkModel.uplink_seconds``) — the server waits
    for the whole cohort.
    """
    import numpy as np
    from repro.core.network import make_network
    round_fn = jax.jit(make_cfl_round(loss_fn, cfg))
    state = init_cfl_state(params, cfg, seed=seed)
    rng = np.random.default_rng(seed)
    bytes_per_client = comm_lib.IdentityCodec().bytes_per_client(params)
    net = None if cfg.network is None else \
        make_network(cfg.network, cfg.m, seed=seed)
    history: dict[str, list] = {"round": [], "loss": [], "lr": [],
                                "wire_bytes": [], "wall_us": [], "eval": {}}
    if net is not None:
        history["sim_time"] = []
    for t in range(rounds):
        ids = rng.choice(cfg.m, size=cfg.cohort, replace=False)
        batches = sample_batches(t, ids)
        t0 = time.perf_counter()
        state, metrics = round_fn(state, jnp.asarray(ids), batches)
        jax.block_until_ready((state.global_params, metrics))
        history["wall_us"].append((time.perf_counter() - t0) * 1e6)
        history["round"].append(t)
        history["loss"].append(float(metrics["loss"]))
        history["lr"].append(float(metrics["lr"]))
        history["wire_bytes"].append(bytes_per_client * cfg.cohort)
        if net is not None:
            up = net.uplink_seconds(bytes_per_client, t)
            history["sim_time"].append(
                cfg.K * net.compute_s + float(up[ids].max()))
        if eval_fn is not None and ((t + 1) % eval_every == 0 or t == rounds - 1):
            ev = eval_fn(state.global_params)
            history["eval"].setdefault("round", []).append(t)
            for k, v in ev.items():
                history["eval"].setdefault(k, []).append(float(v))
    return state, history
