"""Centralized-FL baselines the paper compares against: FedAvg, FedSAM,
and FedPD (the ADMM ancestor, Eqs. 3-5).

Decentralized baselines (D-PSGD, DFedAvg, DFedAvgM, DFedSAM) live in
``core/dfl.py`` since they share the gossip round structure.

These are intentionally simple single-device simulators (vmap over the
sampled cohort); they exist for the faithful-reproduction experiments.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core import admm, sam

PyTree = Any


@dataclasses.dataclass(frozen=True)
class CFLConfig:
    algorithm: str = "fedavg"     # fedavg | fedsam | fedpd
    m: int = 100                  # total clients
    participation: float = 0.1    # cohort fraction per round
    K: int = 5
    lr: float = 0.1
    lr_decay: float = 0.998
    global_lr: float = 1.0
    rho: float = 0.1              # fedsam
    lam: float = 0.1              # fedpd
    weight_decay: float = 5e-4

    @property
    def cohort(self) -> int:
        return max(1, int(round(self.m * self.participation)))


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class CFLState:
    global_params: PyTree
    dual: PyTree                  # (m, ...) — fedpd only (zeros otherwise)
    rng: jax.Array
    round: jax.Array


def init_cfl_state(params: PyTree, cfg: CFLConfig, seed: int = 0) -> CFLState:
    dual = jax.tree.map(
        lambda x: jnp.zeros((cfg.m,) + x.shape, x.dtype), params)
    return CFLState(global_params=params, dual=dual,
                    rng=jax.random.PRNGKey(seed),
                    round=jnp.zeros((), jnp.int32))


def make_cfl_round(loss_fn: Callable[[PyTree, Any, jax.Array], jax.Array],
                   cfg: CFLConfig):
    """Build ``round_fn(state, cohort_ids, batches) -> (state, metrics)``.

    ``cohort_ids``: (cohort,) int32 client indices sampled by the caller.
    ``batches`` leaves: (cohort, K, ...).
    """
    rho = cfg.rho if cfg.algorithm == "fedsam" else 0.0
    loss_and_grad = sam.sam_value_and_grad(loss_fn, rho)
    use_wd = cfg.algorithm in ("fedavg", "fedsam")

    def client_update(x0, dual_i, batches_k, rng, lr_t):
        if cfg.algorithm == "fedpd":
            def body(carry, batch):
                params, rng_ = carry
                rng_, sub = jax.random.split(rng_)
                l, g = loss_and_grad(params, batch, sub)
                params = admm.local_step(params, g, dual_i, x0,
                                         lr=lr_t, lam=cfg.lam)
                return (params, rng_), l

            (xk, _), losses = jax.lax.scan(body, (x0, rng), batches_k)
            new_dual = admm.dual_update(dual_i, xk, x0, lam=cfg.lam)
            # FedPD Eq. 5 server message: x_i - lam * g_hat_i^{t+1}
            msg = jax.tree.map(lambda p, d: p - cfg.lam * d, xk, new_dual)
            return msg, new_dual, jnp.mean(losses)

        def body(carry, batch):
            params, rng_ = carry
            rng_, sub = jax.random.split(rng_)
            l, g = loss_and_grad(params, batch, sub)
            if use_wd and cfg.weight_decay:
                g = jax.tree.map(lambda gi, p: gi + cfg.weight_decay * p,
                                 g, params)
            params = jax.tree.map(lambda p, gi: p - lr_t * gi, params, g)
            return (params, rng_), l

        (xk, _), losses = jax.lax.scan(body, (x0, rng), batches_k)
        return xk, dual_i, jnp.mean(losses)

    def round_fn(state: CFLState, cohort_ids: jax.Array, batches: PyTree):
        lr_t = cfg.lr * (cfg.lr_decay ** state.round.astype(jnp.float32))
        rng, *subs = jax.random.split(state.rng, cfg.cohort + 1)
        subs = jnp.stack(subs)
        cohort_dual = jax.tree.map(lambda d: d[cohort_ids], state.dual)

        msgs, new_duals, losses = jax.vmap(
            client_update, in_axes=(None, 0, 0, 0, None)
        )(state.global_params, cohort_dual, batches, subs, lr_t)

        mean_msg = jax.tree.map(lambda z: jnp.mean(z, axis=0), msgs)
        if cfg.algorithm == "fedpd":
            new_global = mean_msg
        else:
            # server step: x0 + global_lr * (mean(x_i) - x0)
            new_global = jax.tree.map(
                lambda x0, z: x0 + cfg.global_lr * (z - x0),
                state.global_params, mean_msg)

        dual = jax.tree.map(lambda d, nd: d.at[cohort_ids].set(nd),
                            state.dual, new_duals)
        new_state = CFLState(global_params=new_global, dual=dual, rng=rng,
                             round=state.round + 1)
        return new_state, {"loss": jnp.mean(losses), "lr": lr_t}

    return round_fn


def simulate_cfl(loss_fn, eval_fn, params: PyTree, cfg: CFLConfig,
                 sample_batches: Callable[[int, Any], PyTree], rounds: int,
                 seed: int = 0, eval_every: int = 10):
    """sample_batches(t, cohort_ids) -> leaves (cohort, K, ...)."""
    import numpy as np
    round_fn = jax.jit(make_cfl_round(loss_fn, cfg))
    state = init_cfl_state(params, cfg, seed=seed)
    rng = np.random.default_rng(seed)
    history: dict[str, list] = {"round": [], "loss": [], "eval": {}}
    for t in range(rounds):
        ids = rng.choice(cfg.m, size=cfg.cohort, replace=False)
        batches = sample_batches(t, ids)
        state, metrics = round_fn(state, jnp.asarray(ids), batches)
        history["round"].append(t)
        history["loss"].append(float(metrics["loss"]))
        if eval_fn is not None and ((t + 1) % eval_every == 0 or t == rounds - 1):
            ev = eval_fn(state.global_params)
            history["eval"].setdefault("round", []).append(t)
            for k, v in ev.items():
                history["eval"].setdefault(k, []).append(float(v))
    return state, history
