"""Sharpness-Aware Minimization (Foret et al. 2020) as used by
DFedADMM-SAM / DFedSAM / FedSAM (Alg. 1 lines 10-13).

The perturbation uses the *global* l2 norm across the whole client
parameter vector:  x_breve = x + rho * g1 / ||g1||.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

PyTree = Any


def global_norm(tree: PyTree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def perturb(params: PyTree, grads: PyTree, rho: float,
            eps: float = 1e-12, use_kernel: bool = False) -> PyTree:
    """x + rho * g / ||g||  (global norm)."""
    norm = global_norm(grads)
    scale = rho / (norm + eps)
    if use_kernel:
        from repro.kernels import ops as kops
        return jax.tree.map(lambda x, g: kops.sam_scale(x, g, scale), params, grads)
    return jax.tree.map(
        lambda x, g: (x.astype(jnp.float32)
                      + scale * g.astype(jnp.float32)).astype(x.dtype),
        params, grads)


def sam_value_and_grad(loss_fn: Callable[[PyTree, Any, jax.Array], jax.Array],
                       rho: float, use_kernel: bool = False
                       ) -> Callable[[PyTree, Any, jax.Array], tuple]:
    """Wrap a loss into a (loss, grad) oracle with SAM perturbation.

    rho == 0 reduces exactly to a plain gradient oracle (paper Remark:
    "by setting rho = 0, we obtain ... DFedADMM").  The reported loss is
    always the loss at the *unperturbed* point.
    """
    vg = jax.value_and_grad(loss_fn)

    if rho == 0.0:
        def plain(params, batch, rng):
            return vg(params, batch, rng)
        return plain

    grad = jax.grad(loss_fn)

    def sam(params, batch, rng):
        l, g1 = vg(params, batch, rng)             # line 10
        x_breve = perturb(params, g1, rho, use_kernel=use_kernel)  # line 11
        return l, grad(x_breve, batch, rng)        # line 12 (same minibatch)

    return sam


def sam_grad_fn(loss_fn, rho: float, use_kernel: bool = False):
    """Gradient-only variant of :func:`sam_value_and_grad`."""
    vg = sam_value_and_grad(loss_fn, rho, use_kernel=use_kernel)

    def g(params, batch, rng):
        return vg(params, batch, rng)[1]

    return g
