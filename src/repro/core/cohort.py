"""Cohort virtualization: million-client populations, cohort-sized memory.

The dense ``simulate`` path materializes every client's parameters,
solver state, codec residuals, and push-sum weights in the stacked
``DFLState`` — population size is device-memory-bound at a few dozen
clients.  This module splits the population in two:

* a large **cold** set whose per-client state lives in a host-side
  :class:`ClientStore` (numpy rows, touched clients only);
* a small **hot cohort** of ``cfg.m`` slots gathered per round by
  ``participation.cohort_ids``, run through the *unchanged* jitted round
  (``make_train_round`` — same solver / transport / codec / threat
  composition, same static shapes, so membership changes never
  recompile), and scattered back.

Device-resident state drops from O(n_virtual) to O(cohort); the gossip
topology, the participation scenario, and the network cost model all
operate over the cohort *slots*, which is exactly the sub-sampled gossip
regime of the cross-device literature (arXiv:2107.12048).  With
``cohort == n_virtual`` the gather is the identity permutation and every
round is bit-identical to the dense path (pinned by
tests/test_cohort.py for every registered solver).

``execution="async"`` runs per-cohort ticks instead of rounds: the
``async_engine.VirtualScheduler`` event queue spans the whole virtual
population, and each tick's ready clients board the hot cohort for one
masked synchronous gossip step — the event-driven engine's semantics at
a scale where its per-client publication buffers could never be
device-resident.
"""
from __future__ import annotations

import dataclasses
import inspect
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import comm as comm_lib
from repro.core import solvers as solvers_lib
from repro.core.dfl import DFLConfig, DFLState, mean_params
from repro.core.participation import (ParticipationSpec, cohort_ids,
                                      participation_schedule)

PyTree = Any


class ClientStore:
    """Host-side store of per-client hot state (params, solver state,
    codec residuals, push-sum weights) for ``n_virtual`` clients.

    Sparse by construction: at init every client is *identical* (the
    paper's common init x^0 broadcast, zero solver/codec state, uniform
    push-sum weight), so the store keeps ONE template row per leaf and a
    ``{client_id: rows}`` dict for clients a cohort has touched — host
    memory scales with the number of *trained* clients, device memory
    with the cohort.  Per-client PRNG keys are the exception: they are
    ``jax.random.split(PRNGKey(seed), n_virtual)`` exactly like the
    dense ``init_state`` (8 bytes/client — fine at 1e6), so slot ``i``
    of a full-population cohort sees the dense path's key bit for bit.

    The round counter is global (one counter for the whole population,
    like the dense path's ``state.round``): learning-rate decay and the
    per-client ``fold_in`` derivations depend only on it, which is what
    makes the full-cohort reduction exact.
    """

    def __init__(self, params_single: PyTree, cfg: DFLConfig, seed: int = 0):
        if cfg.n_virtual < 1:
            raise ValueError(
                "ClientStore needs cfg.n_virtual >= 1 (the virtual "
                f"population size), got {cfg.n_virtual}")
        self.n_virtual = cfg.n_virtual
        self.cohort = cfg.m
        # one cohort-sized init gives the template row: every client's
        # initial state is identical (rng keys are handled separately)
        stacked = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (cfg.m,) + x.shape),
            params_single)
        solver = solvers_lib.make_solver(cfg)
        hot = (stacked, solver.init_state(cfg, stacked),
               comm_lib.init_comm_state(cfg, stacked))
        leaves, self._treedef = jax.tree.flatten(hot)
        self._templates = [np.asarray(leaf[0]) for leaf in leaves]
        self._rows: dict[int, list[np.ndarray]] = {}
        self._keys = np.asarray(
            jax.random.split(jax.random.PRNGKey(seed), self.n_virtual))
        self.round = 0

    @property
    def touched(self) -> int:
        """Number of clients holding non-template state (host rows)."""
        return len(self._rows)

    def host_bytes(self) -> int:
        """Host memory of the materialized rows (telemetry)."""
        return sum(sum(r.nbytes for r in rows)
                   for rows in self._rows.values())

    def gather(self, ids: np.ndarray) -> DFLState:
        """Stack the ``ids`` rows (templates for untouched clients) into
        a hot cohort-shaped ``DFLState`` on device."""
        ids = np.asarray(ids)
        picked = [self._rows.get(int(i)) for i in ids]
        leaves = [
            jnp.asarray(np.stack(
                [rows[k] if rows is not None else tmpl for rows in picked]))
            for k, tmpl in enumerate(self._templates)]
        params, solver, comm = jax.tree.unflatten(self._treedef, leaves)
        return DFLState(params=params, solver=solver,
                        rng=jnp.asarray(self._keys[ids]),
                        round=jnp.asarray(self.round, jnp.int32),
                        comm=comm)

    def scatter(self, ids: np.ndarray, state: DFLState,
                keep: np.ndarray | None = None) -> None:
        """Write the cohort rows back to their virtual clients.

        ``keep`` (cohort,) bool skips slots whose client did not run
        this round (padding slots of an under-full async tick) — their
        store rows stay untouched.  The global round counter follows the
        state's (the round loop already incremented it).
        """
        ids = np.asarray(ids)
        hot = (state.params, state.solver, state.comm)
        host = [np.asarray(leaf) for leaf in jax.tree.leaves(hot)]
        for slot, cid in enumerate(ids):
            if keep is not None and not keep[slot]:
                continue
            self._rows[int(cid)] = [h[slot] for h in host]
        self.round = int(state.round)


def _call_sampler(sample_batches: Callable, t: int, ids: np.ndarray):
    """``sample_batches(t, ids)`` when the sampler is cohort-aware (two
    positional parameters), the dense ``sample_batches(t)`` otherwise."""
    try:
        n_params = len(inspect.signature(sample_batches).parameters)
    except (TypeError, ValueError):
        n_params = 1
    return sample_batches(t, ids) if n_params >= 2 else sample_batches(t)


def simulate_virtual(loss_fn, eval_fn, params_single: PyTree, cfg: DFLConfig,
                     sample_batches: Callable, rounds: int, seed: int = 0,
                     eval_every: int = 10, verbose: bool = False):
    """``simulate`` over a virtualized population (``cfg.n_virtual`` > 0).

    Per round: draw the hot cohort (``participation.cohort_ids``),
    gather its state from the :class:`ClientStore`, run the identical
    jitted round (topology, participation, codec, transport, threat,
    and network model all over the ``cfg.m`` cohort slots), scatter the
    results back.  The history contract matches ``simulate``
    (loss/lr/consensus/wire_bytes/sim_time/... rows per round) plus
    ``history["store_touched"]`` — the cold-store row count, the number
    that stays flat in device memory no matter how large the population.

    ``sample_batches(t, ids)``: a cohort-aware sampler receives the
    round's virtual-client ids so each virtual client keeps its own data
    shard; a single-argument dense sampler is called as ``(t)``
    unchanged (the full-cohort bit-identity path).

    ``execution="async"`` switches to per-cohort ticks driven by
    ``async_engine.VirtualScheduler`` — ``rounds`` then counts ticks,
    and ``history["ticked"]`` records each tick's cohort fill fraction.
    """
    from repro.core.gossip import time_varying_specs

    if cfg.n_virtual < cfg.m:
        raise ValueError(
            f"simulate_virtual needs n_virtual >= m, got "
            f"n_virtual={cfg.n_virtual}, m={cfg.m}")
    if cfg.execution == "async":
        return _simulate_virtual_async(loss_fn, eval_fn, params_single, cfg,
                                       sample_batches, rounds, seed=seed,
                                       eval_every=eval_every, verbose=verbose)
    if cfg.transport == "ppermute" and cfg.topology in ("random", "drandom"):
        raise ValueError(
            f"topology={cfg.topology!r} draws a fresh non-circulant graph "
            "every round, but the ppermute transport compiles one static "
            "neighbour pattern; use transport='dense' for time-varying "
            "topologies")
    m = cfg.m
    specs = time_varying_specs(cfg.topology, m, rounds, degree=cfg.degree,
                               base_seed=seed, weights=cfg.weights)
    spec0 = specs[0]
    from repro.core.dfl import make_train_round
    round_fn = jax.jit(make_train_round(loss_fn, cfg, spec=spec0))
    store = ClientStore(params_single, cfg, seed=seed)
    transport = comm_lib.make_transport(cfg, spec=spec0)
    codec = comm_lib.make_codec(cfg)
    bytes_per_client = codec.bytes_per_client(params_single)
    if solvers_lib.make_solver(cfg).tracks:
        # a tracking solver's second (uncompressed) gossip message —
        # same accounting as the dense path
        bytes_per_client += comm_lib.IdentityCodec().bytes_per_client(
            params_single)

    net = cfg.make_network_model(seed=seed)
    transfer = None if net is None or \
        cfg.participation.mode != "deadline" else [
        net.transfer_times(s.matrix, bytes_per_client, t)
        for t, s in enumerate(specs)]
    trivial = cfg.participation.is_trivial
    sched = None if trivial else participation_schedule(
        cfg.participation, m, rounds, cfg.K, transfer_times=transfer)

    history: dict[str, list] = {"round": [], "loss": [], "lr": [],
                                "consensus_sq": [], "dual_norm": [],
                                "wire_bytes": [], "wall_us": [],
                                "store_touched": []}
    if not trivial:
        history["participation"] = []
    if net is not None:
        history["sim_time"] = []
    for k in codec.metric_names():
        history[k] = []
    eval_hist: dict[str, list] = {}
    state = None
    for t in range(rounds):
        ids = cohort_ids(cfg.n_virtual, m, seed, t)
        batches = _call_sampler(sample_batches, t, ids)
        t0 = time.perf_counter()
        state = store.gather(ids)
        if trivial:
            plan = transport.prepare(specs[t])
            state, metrics = round_fn(state, batches, plan)
            n_active = m
        else:
            rp = sched[t]
            plan = transport.prepare(specs[t], rp.active)
            state, metrics = round_fn(state, batches, plan,
                                      jnp.asarray(rp.active),
                                      jnp.asarray(rp.steps))
            n_active = int(rp.active.sum())
        jax.block_until_ready((state.params, metrics))
        store.scatter(ids, state)
        history["wall_us"].append((time.perf_counter() - t0) * 1e6)
        if not trivial:
            history["participation"].append(float(metrics["participation"]))
        history["wire_bytes"].append(bytes_per_client * n_active)
        history["store_touched"].append(store.touched)
        if net is not None:
            act = None if trivial else sched[t].active
            if cfg.participation.mode == "deadline":
                history["sim_time"].append(net.deadline_round_time(
                    transfer[t], sched[t].active, cfg.K))
            else:
                tiers = transport.sim_tiers(specs[t], act)
                if tiers is not None:
                    history["sim_time"].append(net.tiered_round_time(
                        tiers, bytes_per_client, t, cfg.K, active=act))
                else:
                    history["sim_time"].append(net.round_time(
                        specs[t].matrix, bytes_per_client, t, cfg.K,
                        active=act))
        history["round"].append(t)
        for k in ("loss", "lr", "consensus_sq", "dual_norm") \
                + codec.metric_names():
            history[k].append(float(metrics[k]))
        if eval_fn is not None and ((t + 1) % eval_every == 0
                                    or t == rounds - 1):
            ev = eval_fn(mean_params(state.params))
            eval_hist.setdefault("round", []).append(t)
            for k, v in ev.items():
                eval_hist.setdefault(k, []).append(float(v))
            if verbose:
                print(f"[cohort] round {t}: {ev}")
    history["eval"] = eval_hist
    return state, history


def _simulate_virtual_async(loss_fn, eval_fn, params_single: PyTree,
                            cfg: DFLConfig, sample_batches: Callable,
                            ticks: int, seed: int = 0, eval_every: int = 10,
                            verbose: bool = False):
    """Per-cohort ticks: each tick's ready virtual clients board the hot
    cohort for one masked synchronous gossip step (see module docs)."""
    from repro.core.async_engine import VirtualScheduler
    from repro.core.dfl import make_train_round
    from repro.core.gossip import time_varying_specs

    m = cfg.m
    # the tick round is a *masked* synchronous round over the cohort:
    # force the masked local phase and run the scheduler ourselves
    tick_cfg = dataclasses.replace(
        cfg, execution="sync",
        participation=ParticipationSpec(mode="uniform", p=1.0,
                                        seed=cfg.participation.seed))
    specs = time_varying_specs(cfg.topology, m, ticks, degree=cfg.degree,
                               base_seed=seed, weights=cfg.weights)
    spec0 = specs[0]
    round_fn = jax.jit(make_train_round(loss_fn, tick_cfg, spec=spec0))
    store = ClientStore(params_single, cfg, seed=seed)
    transport = comm_lib.make_transport(tick_cfg, spec=spec0)
    codec = comm_lib.make_codec(cfg)
    bytes_per_client = codec.bytes_per_client(params_single)
    if solvers_lib.make_solver(cfg).tracks:
        bytes_per_client += comm_lib.IdentityCodec().bytes_per_client(
            params_single)
    net = cfg.make_network_model(seed=seed)
    sched = VirtualScheduler(cfg, net, cfg.n_virtual, bytes_per_client)

    history: dict[str, list] = {"round": [], "loss": [], "lr": [],
                                "consensus_sq": [], "dual_norm": [],
                                "wire_bytes": [], "wall_us": [],
                                "store_touched": [], "sim_time": [],
                                "ticked": []}
    for k in codec.metric_names():
        history[k] = []
    eval_hist: dict[str, list] = {}
    state = None
    full_steps = np.full(m, cfg.K, dtype=np.int64)
    for t in range(ticks):
        ready = np.sort(sched.step(t))
        history["round"].append(t)
        history["sim_time"].append(cfg.tick_s)
        history["ticked"].append(len(ready) / m)
        if len(ready) == 0:
            # empty window: no jit call, NaN telemetry row (the async
            # engine's convention)
            for k in ("loss", "lr", "consensus_sq", "dual_norm") \
                    + codec.metric_names():
                history[k].append(float("nan"))
            history["wire_bytes"].append(0)
            history["wall_us"].append(0.0)
            history["store_touched"].append(store.touched)
            continue
        # pad the cohort to its static shape with queued (inactive) ids
        active = np.zeros(m, dtype=bool)
        active[:len(ready)] = True
        if len(ready) < m:
            pool = np.setdiff1d(np.arange(cfg.n_virtual), ready)[
                :m - len(ready)]
            ids = np.concatenate([ready, pool])
        else:
            ids = ready
        batches = _call_sampler(sample_batches, t, ids)
        t0 = time.perf_counter()
        state = store.gather(ids)
        plan = transport.prepare(specs[t], active)
        state, metrics = round_fn(state, batches, plan,
                                  jnp.asarray(active),
                                  jnp.asarray(np.where(active, full_steps,
                                                       0)))
        jax.block_until_ready((state.params, metrics))
        store.scatter(ids, state, keep=active)
        sched.advance(ready)
        history["wall_us"].append((time.perf_counter() - t0) * 1e6)
        history["wire_bytes"].append(bytes_per_client * len(ready))
        history["store_touched"].append(store.touched)
        for k in ("loss", "lr", "consensus_sq", "dual_norm") \
                + codec.metric_names():
            history[k].append(float(metrics[k]))
        if eval_fn is not None and ((t + 1) % eval_every == 0
                                    or t == ticks - 1):
            ev = eval_fn(mean_params(state.params))
            eval_hist.setdefault("round", []).append(t)
            for k, v in ev.items():
                eval_hist.setdefault(k, []).append(float(v))
            if verbose:
                print(f"[cohort-async] tick {t}: {ev}")
    history["eval"] = eval_hist
    return state, history
