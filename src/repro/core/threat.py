"""Adversarial & privacy scenario layer: attacks, robust mixing, DP wire.

The scenario engine models *absence* (sampling, dropout, stragglers,
deadlines — ``repro.core.participation``); this module models *malice*
and *privacy*, as a fifth pluggable layer next to solver / transport /
codec / network.  Three pieces, each a registry:

``Attack`` — what a Byzantine client sends.  A seeded adversary mask
(:func:`adversary_mask`, persistent across rounds) enters the jitted
round as an (m,) bool array; the attack perturbs the *outgoing* gossip
message ``z`` of the masked clients before the codec sees it, so an
adversary corrupts the protocol from inside it (its wire bytes, its
error-feedback residual, its push-sum weight all stay protocol-shaped).
Builtins: ``signflip`` (send ``-scale * z``), ``gaussian`` (additive
``scale``-std noise), ``zero`` (drop: send an all-zero model), and
``collude`` (model replacement: every adversary transmits the identical
``scale``-amplified mean of the coalition's models).

``RobustAggregator`` — what an honest receiver does about it.  Applied
as a ``Transport``-level transform (:class:`RobustTransport` wraps any
inner transport), so robustness composes with the dense, ppermute, and
push-sum paths *and* with the async engine's effective-subgraph plans
instead of forking the round loop.  Every aggregator consumes the same
object the plain mix does — this round's (m, m) effective weight matrix
(masked dense plan, ``effective_matrix`` tick plan, or the push-sum
column plan with the sender weights folded in) — and treats row ``i``'s
support ``w[i, j] > 0`` as receiver ``i``'s in-neighbourhood.  Builtins:
``mean`` (renormalized weighted mean — the plain gossip step, and the
identity wiring: ``robust="mean"`` never wraps the transport), trimmed
mean (``trimmed_mean``: per coordinate, drop the ``robust_trim``
fraction of extreme values per side, weighted-average the rest),
coordinate ``median``, and ``krum`` (select the one candidate whose
summed distance to its closest peers is smallest — Blanchard et al.'s
Krum, per receiver neighbourhood).  An identity plan row (a masked-out
or non-ticking client) reduces every builtin to an exact passthrough of
the client's own message, so the participation/async freezing
invariants hold unchanged under robust mixing.

``DPCodec`` — what leaves an honest client.  A ``MessageCodec``
(``DFLConfig(codec="dp")``): per-client global-L2 clip to ``dp_clip``
then Gaussian noise with std ``dp_noise * dp_clip`` (the standard
noise-multiplier convention).  The *clipping* error rides the existing
error-feedback residual state (``DFLState.comm["residual"]``) so the
clipped-off mass telescopes like any lossy codec's; the *noise* is
deliberately excluded from the feedback — fed-back noise would cancel
over rounds and void the privacy.  Per-round telemetry
(``history["dp_clip_frac"]`` / ``history["dp_noise_mult"]``) flows
through ``MessageCodec.wire_metrics``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core._registry import FactoryRegistry
from repro.core.comm import MessageCodec, Transport, _gate_tree, _leaf_rngs

PyTree = Any

ATTACKS = ("signflip", "gaussian", "zero", "collude")
AGGREGATORS = ("mean", "trimmed_mean", "median", "krum")


# ---------------------------------------------------------------------------
# Threat declaration
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ThreatSpec:
    """Who attacks and how: ``frac`` of the m clients (seeded, persistent
    across rounds) run ``attack`` with amplification ``scale``."""

    attack: str = "signflip"
    frac: float = 0.0       # adversary fraction of m (floor(frac * m) clients)
    scale: float = 1.0      # attack amplification factor
    seed: int = 0           # seeds the adversary selection

    def __post_init__(self):
        if self.attack not in attack_names():
            raise ValueError(
                f"unknown attack {self.attack!r}; expected one of "
                f"{attack_names()}")
        if not 0.0 <= self.frac <= 1.0:
            raise ValueError(
                f"ThreatSpec.frac must be in [0, 1], got {self.frac}")
        if not math.isfinite(self.scale):
            raise ValueError(
                f"ThreatSpec.scale must be finite, got {self.scale}")

    @property
    def is_trivial(self) -> bool:
        """True when no client attacks (the round loop then builds the
        exact unthreatened computation — bit-identical to no threat)."""
        return self.frac == 0.0

    def n_adversaries(self, m: int) -> int:
        return int(math.floor(self.frac * m))


def adversary_mask(spec: ThreatSpec, m: int) -> np.ndarray:
    """(m,) bool — the seeded persistent adversary set: ``floor(frac*m)``
    clients drawn without replacement from ``default_rng(spec.seed)``.
    Host-side numpy; enters the jitted round as data, like the gossip
    matrices and participation masks."""
    n = spec.n_adversaries(m)
    mask = np.zeros(m, dtype=bool)
    if n > 0:
        idx = np.random.default_rng(spec.seed).choice(m, size=n,
                                                      replace=False)
        mask[idx] = True
    return mask


# ---------------------------------------------------------------------------
# Attacks: perturb the outgoing message z inside the jitted round
# ---------------------------------------------------------------------------

class Attack:
    """Protocol: ``perturb(z, adv, rng) -> z'`` inside jit.

    ``z`` is the (m, ...)-stacked outgoing messages, ``adv`` the (m,)
    bool adversary mask for this round (already intersected with the
    participation mask: a client that transmits nothing cannot attack),
    ``rng`` a per-round PRNG key.  Honest rows must pass through
    bit-identically — implementations compute the attacked tree and gate
    it with ``_gate_tree(adv, attacked, z)``.
    """

    name: str = ""

    def perturb(self, z: PyTree, adv: jax.Array, rng: jax.Array) -> PyTree:
        raise NotImplementedError


class SignFlipAttack(Attack):
    """Send ``-scale * z``: the classic sign-flipping Byzantine client
    (scale > 1 amplifies the reversed update)."""

    def __init__(self, scale: float = 1.0):
        self.scale = float(scale)
        self.name = f"signflip[x{self.scale:g}]"

    def perturb(self, z, adv, rng):
        s = jnp.float32(self.scale)
        bad = jax.tree.map(
            lambda a: (-s * a.astype(jnp.float32)).astype(a.dtype), z)
        return _gate_tree(adv, bad, z)


class GaussianAttack(Attack):
    """Send ``z + scale * N(0, I)``: heavy additive noise on the wire."""

    def __init__(self, scale: float = 1.0):
        self.scale = float(scale)
        self.name = f"gaussian[{self.scale:g}]"

    def perturb(self, z, adv, rng):
        leaves, treedef = jax.tree.flatten(z)
        s = jnp.float32(self.scale)
        bad = [
            (leaf.astype(jnp.float32)
             + s * jax.random.normal(key, leaf.shape, jnp.float32)
             ).astype(leaf.dtype)
            for leaf, key in zip(leaves, _leaf_rngs(rng, leaves))]
        return _gate_tree(adv, jax.tree.unflatten(treedef, bad), z)


class ZeroAttack(Attack):
    """Send the all-zero model: a drop/omission failure that still
    occupies its slot in the mixing matrix."""

    name = "zero"

    def perturb(self, z, adv, rng):
        return _gate_tree(adv, jax.tree.map(jnp.zeros_like, z), z)


class ColludeAttack(Attack):
    """Colluding model replacement: every adversary transmits the SAME
    message — the ``scale``-amplified mean of the coalition's own
    models — so the coalition pulls each neighbourhood toward one agreed
    replacement point instead of adding independent noise."""

    def __init__(self, scale: float = 1.0):
        self.scale = float(scale)
        self.name = f"collude[x{self.scale:g}]"

    def perturb(self, z, adv, rng):
        af = adv.astype(jnp.float32)
        n = jnp.maximum(jnp.sum(af), 1.0)
        s = jnp.float32(self.scale)

        def leaf(a):
            w = af.reshape((a.shape[0],) + (1,) * (a.ndim - 1))
            mu = jnp.sum(a.astype(jnp.float32) * w, axis=0) / n
            return jnp.broadcast_to(s * mu, a.shape).astype(a.dtype)

        return _gate_tree(adv, jax.tree.map(leaf, z), z)


_ATTACK_REGISTRY = FactoryRegistry("attack", ATTACKS)


def register_attack(name: str, factory, overwrite: bool = False) -> None:
    """Register ``factory(spec: ThreatSpec) -> Attack`` under ``name``.

    Mirrors ``comm.register_codec``: once registered the attack is
    selectable via ``ThreatSpec(attack=name)`` (validated at
    construction) with no round-loop changes."""
    _ATTACK_REGISTRY.register(name, factory, overwrite)


def attack_names() -> tuple[str, ...]:
    """All selectable attack names: builtins plus registered ones."""
    return _ATTACK_REGISTRY.names()


def make_attack(spec: ThreatSpec) -> Attack:
    """Build the attack named by ``spec.attack`` (builtin or registered)."""
    name = spec.attack
    if name in _ATTACK_REGISTRY:
        return _ATTACK_REGISTRY.build(name, spec)
    if name == "signflip":
        return SignFlipAttack(spec.scale)
    if name == "gaussian":
        return GaussianAttack(spec.scale)
    if name == "zero":
        return ZeroAttack()
    if name == "collude":
        return ColludeAttack(spec.scale)
    raise ValueError(
        f"unknown attack {name!r}; expected one of {attack_names()}")


# ---------------------------------------------------------------------------
# Robust aggregators: per-receiver robust statistics over the plan support
# ---------------------------------------------------------------------------

class RobustAggregator:
    """Protocol: ``aggregate(z, w) -> x`` inside jit.

    ``z`` is the (m, ...)-stacked messages, ``w`` this round's (m, m)
    effective weight matrix — row ``i`` is receiver ``i``; support is
    ``w[i, j] > 0`` (self-loops included).  Implementations must reduce
    an identity row (support = {i}, weight 1) to an exact bitwise
    passthrough of ``z_i``: the masked participation path and the async
    engine both park frozen clients on identity rows.
    """

    name: str = ""

    def aggregate(self, z: PyTree, w: jax.Array) -> PyTree:
        raise NotImplementedError


def _map_flat(z, fn):
    """Apply ``fn(flat) -> flat'`` per leaf in (m, d) f32 view, restoring
    shape and dtype."""
    def leaf(a):
        m = a.shape[0]
        out = fn(a.astype(jnp.float32).reshape(m, -1))
        return out.reshape(a.shape).astype(a.dtype)
    return jax.tree.map(leaf, z)


class MeanAggregator(RobustAggregator):
    """Renormalized weighted mean — the plain gossip step.  With a
    row-stochastic plan this is exactly ``mixing.mix_dense``; with the
    push-sum effective weights ``P * pi`` the renormalization IS the
    push-sum de-bias.  (``robust="mean"`` never reaches this class — the
    round keeps the unwrapped transport for bit-identity — but it is
    registered so tests and user code can call the mean through the same
    aggregator API.)"""

    name = "mean"

    def aggregate(self, z, w):
        w = w.astype(jnp.float32)
        den = jnp.sum(w, axis=1)

        def fn(flat):
            return jnp.einsum("ij,jd->id", w, flat) / \
                jnp.maximum(den, 1e-12)[:, None]
        return _map_flat(z, fn)


class TrimmedMeanAggregator(RobustAggregator):
    """Coordinate-wise weighted trimmed mean.

    Per receiver and per coordinate: sort the support values, drop the
    ``floor(trim * n_i)`` smallest and largest (capped so at least one
    survives), and weighted-average the survivors with their plan
    weights renormalized.  At ``trim=0`` this reduces to the plain
    weighted mean (the zero-adversary property the tests pin); a
    ``trim`` at least the adversary fraction discards every Byzantine
    coordinate that lands in the extremes.
    """

    def __init__(self, trim: float = 0.25):
        if not 0.0 <= trim < 0.5:
            raise ValueError(
                f"trimmed_mean trim fraction must be in [0, 0.5), "
                f"got {trim}")
        self.trim = float(trim)
        self.name = f"trimmed_mean[{self.trim:g}]"

    def aggregate(self, z, w):
        w = w.astype(jnp.float32)
        sup = w > 0.0                                       # (mr, ms)
        n = jnp.sum(sup, axis=1).astype(jnp.int32)          # (mr,)
        t = jnp.minimum(
            jnp.floor(jnp.float32(self.trim) * n.astype(jnp.float32)
                      ).astype(jnp.int32),
            (n - 1) // 2)                                   # (mr,)
        lo = t[:, None, None]
        hi = (n - t)[:, None, None]

        def fn(flat):                                       # (ms, d)
            ms = flat.shape[0]
            vb = jnp.where(sup[:, :, None], flat[None, :, :], jnp.inf)
            order = jnp.argsort(vb, axis=1)                 # (mr, ms, d)
            vs = jnp.take_along_axis(vb, order, axis=1)
            ws = jnp.take_along_axis(
                jnp.broadcast_to(w[:, :, None], vb.shape), order, axis=1)
            rank = jnp.arange(ms)[None, :, None]
            keep = (rank >= lo) & (rank < hi)
            num = jnp.sum(jnp.where(keep, ws * vs, 0.0), axis=1)
            den = jnp.sum(jnp.where(keep, ws, 0.0), axis=1)
            return num / jnp.maximum(den, 1e-12)
        return _map_flat(z, fn)


class MedianAggregator(RobustAggregator):
    """Coordinate-wise median over the support (unweighted — the median
    is an order statistic; the plan weights only define membership)."""

    name = "median"

    def aggregate(self, z, w):
        sup = w.astype(jnp.float32) > 0.0
        n = jnp.sum(sup, axis=1).astype(jnp.int32)
        lo = ((n - 1) // 2)[:, None, None]
        hi = (n // 2)[:, None, None]

        def fn(flat):
            vb = jnp.where(sup[:, :, None], flat[None, :, :], jnp.inf)
            vs = jnp.sort(vb, axis=1)                       # (mr, ms, d)
            a = jnp.take_along_axis(vs, lo, axis=1)[:, 0, :]
            b = jnp.take_along_axis(vs, hi, axis=1)[:, 0, :]
            return 0.5 * (a + b)
        return _map_flat(z, fn)


class KrumAggregator(RobustAggregator):
    """Krum-style distance filtering: per receiver, select the ONE
    support candidate whose summed squared distance to its
    ``n_i - f_i - 2`` closest support peers is smallest (``f_i =
    floor(f_frac * n_i)`` assumed Byzantine per neighbourhood).
    Distances are global — summed over every leaf of the message — so a
    replacement model cannot hide in one layer.  Score ties are real,
    not a corner case — any mutually-closest pair ties when ``nsel = 1``
    (the shared pair distance is both candidates' score) — so selection
    is lexicographic: smallest score, then smallest total distance to
    the support peers, then the receiver's own candidate.  All three
    keys are permutation-invariant statistics of the neighbourhood, so
    relabeling clients relabels the selection."""

    def __init__(self, f_frac: float = 0.25):
        if not 0.0 <= f_frac < 0.5:
            raise ValueError(
                f"krum Byzantine fraction must be in [0, 0.5), "
                f"got {f_frac}")
        self.f_frac = float(f_frac)
        self.name = f"krum[{self.f_frac:g}]"

    def aggregate(self, z, w):
        w = w.astype(jnp.float32)
        sup = w > 0.0
        m = sup.shape[0]
        n = jnp.sum(sup, axis=1).astype(jnp.int32)
        f = jnp.floor(jnp.float32(self.f_frac) * n.astype(jnp.float32)
                      ).astype(jnp.int32)
        nsel = jnp.clip(n - f - 2, 1, jnp.maximum(n - 1, 1))

        leaves, treedef = jax.tree.flatten(z)
        d2 = jnp.zeros((m, m), jnp.float32)
        for a in leaves:
            flat = a.astype(jnp.float32).reshape(m, -1)
            d2 = d2 + jnp.sum(
                jnp.square(flat[:, None, :] - flat[None, :, :]), axis=-1)

        eye = jnp.eye(m, dtype=bool)
        # (receiver i, candidate j, peer k): peers restricted to i's
        # support, self-distance excluded
        dd = jnp.where(sup[:, None, :] & ~eye[None, :, :],
                       d2[None, :, :], jnp.inf)
        ds = jnp.sort(dd, axis=2)
        rank = jnp.arange(m)[None, None, :]
        score = jnp.sum(
            jnp.where(rank < nsel[:, None, None], ds, 0.0), axis=2)
        score = jnp.where(sup, score, jnp.inf)              # (mr, ms)
        total = jnp.sum(jnp.where(jnp.isfinite(dd), dd, 0.0), axis=2)
        nonself = 1.0 - jnp.eye(m, dtype=jnp.float32)
        # last key is primary: score, then total, then prefer self
        sel = jnp.lexsort((nonself, total, score), axis=1)[:, 0]
        return jax.tree.map(lambda a: a[sel], z)


_AGGREGATOR_REGISTRY = FactoryRegistry("aggregator", AGGREGATORS)


def register_aggregator(name: str, factory, overwrite: bool = False) -> None:
    """Register ``factory(cfg) -> RobustAggregator`` under ``name``.

    Once registered the aggregator is selectable via
    ``DFLConfig(robust=name)``; ``cfg`` is the full config, so factories
    may read ``robust_trim`` / any field they need."""
    _AGGREGATOR_REGISTRY.register(name, factory, overwrite)


def aggregator_names() -> tuple[str, ...]:
    """All selectable robust-aggregator names: builtins + registered."""
    return _AGGREGATOR_REGISTRY.names()


def make_aggregator(cfg) -> RobustAggregator:
    """Build the aggregator named by ``cfg.robust``."""
    name = getattr(cfg, "robust", "mean")
    if name in _AGGREGATOR_REGISTRY:
        return _AGGREGATOR_REGISTRY.build(name, cfg)
    trim = float(getattr(cfg, "robust_trim", 0.25))
    if name == "mean":
        return MeanAggregator()
    if name == "trimmed_mean":
        return TrimmedMeanAggregator(trim)
    if name == "median":
        return MedianAggregator()
    if name == "krum":
        return KrumAggregator(trim)
    raise ValueError(
        f"unknown robust aggregator {name!r}; expected one of "
        f"{aggregator_names()}")


class RobustTransport(Transport):
    """Wrap any inner transport with a robust aggregation of its plan.

    ``prepare`` delegates to the inner transport (so the participation
    masking, the push-sum column algebra, and the ppermute pattern
    validation all run unchanged) and guarantees the plan reaching
    ``mix`` is the realizable (m, m) weight matrix; ``mix`` replaces the
    weighted contraction with the aggregator's per-receiver robust
    statistic over the plan support.  Push-sum folds the sender weights
    into the effective matrix (``P * pi``) and keeps the ``pi' = P pi``
    contraction, so at ``trim=0`` the weighted trimmed mean reproduces
    the push-sum de-bias exactly.  The async engine's raw
    ``effective_matrix`` plans flow through the dense path untouched.
    On-mesh ppermute is rejected at construction (``make_transport``):
    a robust statistic needs the full neighbourhood materialized, which
    the gated-permute path never does.
    """

    def __init__(self, inner: Transport, agg: RobustAggregator):
        self.inner = inner
        self.agg = agg
        self.kind = inner.kind

    def prepare(self, spec, active=None):
        plan = self.inner.prepare(spec, active)
        if self.kind == "ppermute" and plan is None:
            # full participation: the inner transport's static pattern —
            # realize it as the matrix the aggregator consumes
            plan = jnp.asarray(self.inner.spec.matrix, jnp.float32)
        return plan

    def mix(self, z, plan, aux=None):
        if self.kind == "pushsum":
            if aux is None:
                raise ValueError(
                    "push-sum needs its weight state: initialize "
                    "DFLState.comm via init_state (or Transport.init_aux)")
            pi = aux.astype(jnp.float32)
            eff = plan.astype(jnp.float32) * pi[None, :]
            return self.agg.aggregate(z, eff), plan @ pi
        if self.kind == "hier":
            # robustness per tier: a receiver defends its intra-cluster
            # neighbourhood first, then the head backbone defends the
            # cross-cluster exchange
            x = self.agg.aggregate(z, plan["intra"].astype(jnp.float32))
            return self.agg.aggregate(x, plan["inter"].astype(jnp.float32)), \
                aux
        return self.agg.aggregate(z, jnp.asarray(plan, jnp.float32)), aux

    def init_aux(self, m: int):
        return self.inner.init_aux(m)

    def sim_tiers(self, spec, active=None):
        return self.inner.sim_tiers(spec, active)


# ---------------------------------------------------------------------------
# DP wire codec: per-client clip + Gaussian noise with EF on the clip error
# ---------------------------------------------------------------------------

class DPCodec(MessageCodec):
    """Differentially-private wire: clip then noise, per client.

    Per round, each client's error-compensated message ``e = z + resid``
    is clipped to global L2 norm ``clip`` (one factor across all leaves)
    and Gaussian noise with std ``noise * clip`` (noise-multiplier
    convention) is added per coordinate.  The clipping error ``e -
    clip(e)`` rides the shared error-feedback residual state so clipped
    mass telescopes like any lossy codec's; the noise is EXCLUDED from
    the feedback — carrying it would cancel the randomization over
    rounds and void the privacy.  ``wire_metrics`` reports the fraction
    of (active) clients that hit the clip bound and the configured noise
    multiplier; the round loops thread both into
    ``history["dp_clip_frac"]`` / ``history["dp_noise_mult"]``.
    """

    stateful = True

    def __init__(self, clip: float = 1.0, noise: float = 0.0):
        if not (math.isfinite(clip) and clip > 0.0):
            raise ValueError(f"dp_clip must be > 0, got {clip}")
        if not (math.isfinite(noise) and noise >= 0.0):
            raise ValueError(f"dp_noise must be >= 0, got {noise}")
        self.clip = float(clip)
        self.noise = float(noise)
        self.name = f"dp[clip={self.clip:g},noise={self.noise:g}]"
        self._meta = None

    def init_state(self, stacked_params: PyTree):
        return jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), stacked_params)

    def metric_names(self) -> tuple[str, ...]:
        return ("dp_clip_frac", "dp_noise_mult")

    def encode(self, z, resid=None, rng=None, active=None):
        if rng is None:
            raise ValueError("dp codec needs the round's codec PRNG key "
                             "(the Gaussian mechanism is randomized)")
        leaves, treedef = jax.tree.flatten(z)
        self._meta = ([(l.shape, l.dtype) for l in leaves], treedef)
        rleaves = jax.tree.leaves(resid) if resid is not None else \
            [jnp.zeros(l.shape, jnp.float32) for l in leaves]
        m = leaves[0].shape[0]
        errs = [l.astype(jnp.float32) + r for l, r in zip(leaves, rleaves)]
        sq = sum(jnp.sum(jnp.square(e).reshape(m, -1), axis=1)
                 for e in errs)
        norm = jnp.sqrt(sq)                                   # (m,)
        factor = jnp.minimum(1.0, jnp.float32(self.clip)
                             / jnp.maximum(norm, 1e-12))
        sigma = jnp.float32(self.noise * self.clip)
        wire_leaves, new_resid = [], []
        for e, r, key in zip(errs, rleaves, _leaf_rngs(rng, leaves)):
            fb = factor.reshape((m,) + (1,) * (e.ndim - 1))
            clipped = e * fb
            noisy = clipped
            if self.noise > 0.0:
                noisy = clipped + sigma * jax.random.normal(
                    key, e.shape, jnp.float32)
            rr = e - clipped          # clip error only; noise stays private
            if active is not None:
                rr = _gate_tree(active, rr, r)
            wire_leaves.append(noisy)
            new_resid.append(rr)
        hit = (norm > jnp.float32(self.clip)).astype(jnp.float32)
        if active is not None:
            af = active.astype(jnp.float32)
            clip_frac = jnp.sum(hit * af) / jnp.maximum(jnp.sum(af), 1.0)
        else:
            clip_frac = jnp.mean(hit)
        wire = {"z": jax.tree.unflatten(treedef, wire_leaves),
                "clip_frac": clip_frac,
                "noise_mult": jnp.float32(self.noise)}
        return wire, jax.tree.unflatten(treedef, new_resid)

    def decode(self, wire):
        metas, treedef = self._meta
        leaves = treedef.flatten_up_to(wire["z"])
        return jax.tree.unflatten(
            treedef, [l.astype(dtype) for l, (_, dtype) in
                      zip(leaves, metas)])

    def wire_metrics(self, wire) -> dict:
        return {"dp_clip_frac": wire["clip_frac"],
                "dp_noise_mult": wire["noise_mult"]}

    def bytes_per_client(self, params_single: PyTree) -> int:
        # clip + noise changes values, not representation: f32 per entry
        return int(sum(4 * leaf.size
                       for leaf in jax.tree.leaves(params_single)))
