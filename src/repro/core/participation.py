"""Per-round client participation scenarios for the DFL round loop.

The paper's setting (and the seed implementation) assumes every client
performs K local steps and gossips every round.  Real decentralized
deployments see partial participation: clients sampled in and out per
round, clients that crash mid-round after doing local work, and
persistent stragglers that only complete a few local steps.  This module
models those scenarios host-side as tiny per-round numpy artifacts:

* an ``active`` boolean mask (who contributes to this round's gossip),
* a ``sampled`` mask (who *attempted* the round — differs from ``active``
  when mid-round dropout discards finished local work), and
* a per-client ``steps`` vector (how many of the K local iterations each
  client completes — 0 for inactive clients, < K for stragglers).

The masks are consumed in two places: ``gossip.mask_and_renormalize``
turns the round's gossip matrix into a Definition-1-preserving matrix on
the active subgraph (inactive rows become identity, so those clients hold
their state), and ``dfl.make_train_round`` threads ``active``/``steps``
into the vmapped local update via ``jnp.where`` so the whole round stays
a single jitted computation regardless of who participates.

Everything here is plain numpy on the host — masks are (m,) vectors and
are regenerated per round from a counter-based seed, so schedules are
reproducible without carrying RNG state.

The "deadline" mode couples participation to the network cost model
(``repro.core.network``): the caller threads the model's per-round
transfer times into :func:`round_participation` and clients whose
modeled transfer exceeds the deadline are masked — slow links *cause*
partial participation.  ``simulate`` wires this automatically when
``DFLConfig.network`` is set.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

MODES = ("full", "uniform", "fraction", "schedule", "deadline")


@dataclasses.dataclass(frozen=True)
class ParticipationSpec:
    """Declarative description of a participation scenario.

    mode:
      "full"      — every client, every round (the paper's setting).
      "uniform"   — each client joins independently with probability ``p``.
      "fraction"  — exactly ``round(p * m)`` clients, sampled uniformly
                    without replacement each round.
      "schedule"  — deterministic: ``schedule[t % len(schedule)]`` is the
                    tuple of active client ids for round ``t``.
      "deadline"  — network-coupled: every client attempts the round, but
                    those whose modeled transfer time (from the
                    ``repro.core.network`` cost model, threaded in as
                    ``transfer_times``) exceeds ``deadline`` seconds are
                    masked out of the gossip — slow links *cause* partial
                    participation instead of it being sampled i.i.d.
    deadline:      round deadline in seconds for the "deadline" mode.
    dropout:       probability that a *sampled* client crashes mid-round —
                   it burns the local compute but its update is discarded
                   and it is excluded from the gossip step.
    straggler_frac: fraction of clients (a fixed, seed-chosen set — slow
                   devices are persistently slow) that only complete
                   ``straggler_steps`` of the K local iterations.
    min_active:    lower bound on the number of sampled clients per round;
                   random modes top up from the inactive pool to meet it.
                   0 disables the floor — a round may then sample nobody,
                   in which case every client holds its state and the
                   round's loss metric is NaN (no measurement).
    seed:          base seed; round ``t`` draws from ``default_rng((seed, t))``.
    """

    mode: str = "full"
    p: float = 1.0
    schedule: tuple = ()
    deadline: float = 0.0
    dropout: float = 0.0
    straggler_frac: float = 0.0
    straggler_steps: int = 1
    min_active: int = 2
    seed: int = 0

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(
                f"unknown participation mode {self.mode!r}; expected one of {MODES}")
        if not 0.0 < self.p <= 1.0:
            raise ValueError(f"participation p must be in (0, 1], got {self.p}")
        if not 0.0 <= self.dropout < 1.0:
            raise ValueError(f"dropout must be in [0, 1), got {self.dropout}")
        if not 0.0 <= self.straggler_frac <= 1.0:
            raise ValueError(
                f"straggler_frac must be in [0, 1], got {self.straggler_frac}")
        if self.straggler_steps < 1:
            raise ValueError("straggler_steps must be >= 1")
        if self.min_active < 0:
            raise ValueError("min_active must be >= 0")
        if self.mode == "schedule" and not self.schedule:
            raise ValueError("schedule mode needs a non-empty schedule")
        if self.mode == "deadline" and self.deadline <= 0.0:
            raise ValueError("deadline mode needs a positive deadline "
                             "(seconds of modeled round time)")

    @property
    def is_trivial(self) -> bool:
        """True iff the spec is the paper's full-participation setting, in
        which case the round loop takes the exact seed code path
        (bit-identical trajectories)."""
        return (self.mode == "full" and self.dropout == 0.0
                and self.straggler_frac == 0.0)


@dataclasses.dataclass(frozen=True)
class RoundParticipation:
    """Realized participation for one round."""

    active: np.ndarray    # (m,) bool — contributes to gossip this round
    sampled: np.ndarray   # (m,) bool — attempted the round (>= active)
    steps: np.ndarray     # (m,) int32 — local iterations completed (0 if inactive)

    @property
    def rate(self) -> float:
        """Fraction of clients contributing to this round's gossip."""
        return float(self.active.mean())

    @property
    def wasted(self) -> int:
        """Clients whose local work was discarded by mid-round dropout."""
        return int(self.sampled.sum() - self.active.sum())


def _round_rng(spec: ParticipationSpec, stream: int,
               t: int) -> np.random.Generator:
    # counter-based: (seed, stream, round) must all be non-negative ints
    return np.random.default_rng((spec.seed, stream, t))


_SAMPLE, _DROPOUT, _STRAGGLER = 0, 1, 2


def straggler_set(spec: ParticipationSpec, m: int) -> np.ndarray:
    """(m,) bool mask of the fixed straggler clients."""
    n = int(round(spec.straggler_frac * m))
    mask = np.zeros(m, dtype=bool)
    if n > 0:
        rng = _round_rng(spec, _STRAGGLER, 0)
        mask[rng.choice(m, size=n, replace=False)] = True
    return mask


def sample_mask(spec: ParticipationSpec, m: int, t: int) -> np.ndarray:
    """(m,) bool mask of the clients sampled for round ``t`` (pre-dropout).

    The "deadline" mode samples everybody — whether a sampled client
    *survives* into the gossip is decided by the network cost model in
    :func:`round_participation`, not by this draw."""
    if spec.mode in ("full", "deadline"):
        return np.ones(m, dtype=bool)
    if spec.mode == "schedule":
        ids = np.asarray(spec.schedule[t % len(spec.schedule)], dtype=int)
        if ids.size and (ids.min() < 0 or ids.max() >= m):
            raise ValueError(f"schedule round {t} names clients outside [0, {m})")
        mask = np.zeros(m, dtype=bool)
        mask[ids] = True
        return mask
    rng = _round_rng(spec, _SAMPLE, t)
    if spec.mode == "uniform":
        mask = rng.random(m) < spec.p
    else:  # fraction
        k = max(int(round(spec.p * m)), 1)
        mask = np.zeros(m, dtype=bool)
        mask[rng.choice(m, size=min(k, m), replace=False)] = True
    floor = min(spec.min_active, m)
    short = floor - int(mask.sum())
    if short > 0:
        pool = np.flatnonzero(~mask)
        mask[rng.choice(pool, size=short, replace=False)] = True
    return mask


def round_participation(spec: ParticipationSpec, m: int, t: int, K: int,
                        transfer_times: np.ndarray | None = None
                        ) -> RoundParticipation:
    """Realize the spec for round ``t`` with ``K`` nominal local steps.

    Args:
      spec: the participation scenario.
      m:    number of clients.
      t:    round index (seeds the per-round draws).
      K:    nominal local iterations per round.
      transfer_times: (m,) modeled per-client transfer seconds for this
        round (``NetworkModel.transfer_times``).  Required by the
        "deadline" mode — clients over ``spec.deadline`` are masked,
        with the ``min_active`` floor keeping the fastest clients when
        too few make the cut — and ignored by every other mode.
    """
    sampled = sample_mask(spec, m, t)
    active = sampled.copy()
    if spec.mode == "deadline":
        if transfer_times is None:
            raise ValueError(
                "deadline mode needs the network model's per-round "
                "transfer_times (set DFLConfig.network and run through "
                "simulate, or pass NetworkModel.transfer_times here)")
        transfer_times = np.asarray(transfer_times, dtype=np.float64)
        if transfer_times.shape != (m,):
            raise ValueError(
                f"transfer_times shape {transfer_times.shape} does not "
                f"match m={m}")
        active &= transfer_times <= spec.deadline
        floor = min(spec.min_active, m)
        short = floor - int(active.sum())
        if short > 0:
            # too few clients beat the deadline: keep the fastest ones
            # (deterministic — no RNG draw, the network decides)
            pool = np.flatnonzero(~active)
            order = pool[np.argsort(transfer_times[pool], kind="stable")]
            active[order[:short]] = True
    if spec.dropout > 0.0:
        rng = _round_rng(spec, _DROPOUT, t)
        drops = rng.random(m) < spec.dropout
        active &= ~drops
        if not active.any() and sampled.any():
            # dropout must not erase the whole round: one sampled client
            # survives so the round stays measurable (otherwise the loss
            # metric has no participants to average over)
            active[rng.choice(np.flatnonzero(sampled))] = True
    steps = np.where(straggler_set(spec, m),
                     min(spec.straggler_steps, K), K).astype(np.int32)
    steps[~active] = 0
    return RoundParticipation(active=active, sampled=sampled, steps=steps)


def participation_schedule(spec: ParticipationSpec, m: int, rounds: int,
                           K: int,
                           transfer_times: Sequence[np.ndarray] | None = None
                           ) -> list[RoundParticipation]:
    """One RoundParticipation per round (deterministic in ``spec.seed``).

    ``transfer_times`` — one (m,) vector per round — is required by the
    "deadline" mode (see :func:`round_participation`)."""
    if transfer_times is None:
        transfer_times = [None] * rounds
    if len(transfer_times) != rounds:
        raise ValueError(
            f"need one transfer_times vector per round: "
            f"{len(transfer_times)} != {rounds}")
    return [round_participation(spec, m, t, K, transfer_times=tt)
            for t, tt in zip(range(rounds), transfer_times)]


_COHORT = 3


def cohort_ids(n_virtual: int, cohort: int, seed: int, t: int) -> np.ndarray:
    """Round ``t``'s hot cohort: ``cohort`` virtual-client ids drawn
    uniformly without replacement from the ``n_virtual`` population.

    Counter-based like every scenario stream (``default_rng((seed,
    _COHORT, t))``): the schedule is reproducible from the run seed with
    no carried RNG state.  Ids come back *sorted*, so at ``cohort ==
    n_virtual`` the draw degenerates to ``arange(n_virtual)`` — the
    gather is then the identity permutation and the virtualized round
    reduces bit-identically to the dense ``simulate`` path (pinned by
    tests/test_cohort.py).
    """
    if not 1 <= cohort <= n_virtual:
        raise ValueError(
            f"cohort size must be in [1, n_virtual={n_virtual}], "
            f"got {cohort}")
    if cohort == n_virtual:
        return np.arange(n_virtual)
    rng = np.random.default_rng((seed, _COHORT, t))
    return np.sort(rng.choice(n_virtual, size=cohort, replace=False))
