"""Shared name -> factory registry scaffolding for the pluggable layers.

The codec layer (``comm.register_codec``) and the network layer
(``network.register_network``) both extend a fixed set of builtin names
with user-registered factories; the duplicate-name check, the
``overwrite`` escape hatch, and the builtins-plus-registered name
listing live here exactly once.  (The solver layer keeps its own table
— ``solvers.SOLVERS`` — because its entries also carry simulator
scopes.)
"""
from __future__ import annotations


class FactoryRegistry:
    """Names -> factories, layered over a tuple of builtin names that the
    owning module resolves itself (``kind`` only flavors error text)."""

    def __init__(self, kind: str, builtins: tuple[str, ...]):
        self.kind = kind
        self.builtins = builtins
        self._factories: dict[str, object] = {}

    def register(self, name: str, factory, overwrite: bool = False) -> None:
        if name in self.names() and not overwrite:
            raise ValueError(f"{self.kind} {name!r} already registered "
                             "(pass overwrite=True to replace)")
        self._factories[name] = factory

    def names(self) -> tuple[str, ...]:
        """Builtin names plus registered ones, builtins first."""
        return self.builtins + tuple(n for n in self._factories
                                     if n not in self.builtins)

    def __contains__(self, name) -> bool:
        return name in self._factories

    def build(self, name: str, *args):
        return self._factories[name](*args)
