"""DFedADMM primal/dual updates (Algorithm 1 of the paper).

Everything operates on parameter *pytrees* so the same code drives the
paper's MLP/CNN backbones and the assigned LLM-class architectures.

Notation (paper -> code):
  x_i^t        anchor      post-gossip round-start model of client i
  x_{i,k}^t    params      inner-iterate during the K local steps
  g_hat_i^t    dual        the dual variable ("local gradient controller")
  lambda       lam         ADMM penalty parameter
  eta_l        lr          local learning rate
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ADMMHParams:
    lam: float = 0.1        # penalty parameter lambda (paper default 0.1)
    lr: float = 0.1         # local learning rate eta_l
    rho: float = 0.0        # SAM radius (0 -> plain DFedADMM)
    use_kernel: bool = False  # route the fused update through the Pallas kernel


def tree_zeros_like(tree: PyTree) -> PyTree:
    return jax.tree.map(jnp.zeros_like, tree)


def local_step(params: PyTree, grads: PyTree, dual: PyTree, anchor: PyTree,
               *, lr: float, lam: float, use_kernel: bool = False) -> PyTree:
    """One inner iterate (Alg. 1 line 13 / Eq. 6):

        x_{k+1} = x_k - lr * ( g - dual + (x_k - anchor)/lam )
    """
    if use_kernel:
        from repro.kernels import ops as kops
        return jax.tree.map(
            lambda x, g, d, a: kops.admm_update(x, g, d, a, lr=lr, lam=lam),
            params, grads, dual, anchor)
    inv_lam = 1.0 / lam

    def leaf(x, g, d, a):
        # f32 math, param dtype out (lr may be a traced f32 scalar; do not
        # let it promote bf16 state).
        xf = x.astype(jnp.float32)
        upd = (g.astype(jnp.float32) - d.astype(jnp.float32)
               + inv_lam * (xf - a.astype(jnp.float32)))
        return (xf - lr * upd).astype(x.dtype)

    return jax.tree.map(leaf, params, grads, dual, anchor)


def dual_update(dual: PyTree, params_k: PyTree, anchor: PyTree, *, lam: float
                ) -> PyTree:
    """Alg. 1 line 16:  g_hat^t = g_hat^{t-1} - (x_K - anchor)/lam."""
    inv_lam = 1.0 / lam
    return jax.tree.map(lambda d, xk, a: d - inv_lam * (xk - a),
                        dual, params_k, anchor)


def message(params_k: PyTree, dual_prev: PyTree, *, lam: float) -> PyTree:
    """Alg. 1 line 17:  z = x_K - lam * g_hat^{t-1}  (uses the OLD dual)."""
    return jax.tree.map(lambda xk, d: xk - lam * d, params_k, dual_prev)


# ---------------------------------------------------------------------------
# Closed-form helpers (Appendix Lemmas 2 & 3) — used by tests to pin the
# implementation to the paper's math.
# ---------------------------------------------------------------------------

def gamma(lr: float, lam: float, K: int) -> float:
    """gamma = 1 - (1 - lr/lam)^K."""
    return 1.0 - (1.0 - lr / lam) ** K


def gamma_k(lr: float, lam: float, K: int) -> jnp.ndarray:
    """gamma_k = (lr/lam) (1 - lr/lam)^{K-1-k}, k = 0..K-1.  Sums to gamma."""
    r = lr / lam
    ks = jnp.arange(K)
    return r * (1.0 - r) ** (K - 1 - ks)


def lemma2_delta(grads_seq: PyTree, dual_prev: PyTree, *, lr: float,
                 lam: float, K: int) -> PyTree:
    """Closed form of x_K - anchor given the recorded inner gradients.

    grads_seq: pytree whose leaves have a leading axis of length K holding
    the stochastic gradients g_{i,k} actually used at each inner step.

        x_K - anchor = -lam * sum_k gamma_k g_k + gamma * lam * dual_prev
    """
    gk = gamma_k(lr, lam, K)
    g = gamma(lr, lam, K)

    def leaf(gs, d):
        shaped = gk.reshape((K,) + (1,) * (gs.ndim - 1)).astype(gs.dtype)
        return -lam * jnp.sum(shaped * gs, axis=0) + g * lam * d

    return jax.tree.map(leaf, grads_seq, dual_prev)


def lemma3_dual(grads_seq: PyTree, dual_prev: PyTree, *, lr: float,
                lam: float, K: int) -> PyTree:
    """Closed form of the new dual (Lemma 3):

        g_hat^t = (1-gamma) g_hat^{t-1} + sum_k gamma_k g_k
    """
    gk = gamma_k(lr, lam, K)
    g = gamma(lr, lam, K)

    def leaf(gs, d):
        shaped = gk.reshape((K,) + (1,) * (gs.ndim - 1)).astype(gs.dtype)
        return (1.0 - g) * d + jnp.sum(shaped * gs, axis=0)

    return jax.tree.map(leaf, grads_seq, dual_prev)


# ---------------------------------------------------------------------------
# A full client-local round (K steps + dual + message), independent of how
# clients are laid out (vmap simulation or mesh-sharded).
# ---------------------------------------------------------------------------

def client_round(loss_grad_fn: Callable[[PyTree, Any, jax.Array], PyTree],
                 anchor: PyTree, dual: PyTree, batches: Any, rng: jax.Array,
                 hp: ADMMHParams, K: int,
                 record_grads: bool = False):
    """Run Alg. 1 lines 3-17 for one client.

    loss_grad_fn(params, batch, rng) -> grads pytree (already SAM-perturbed
    when hp.rho > 0; see core/sam.py).
    batches: pytree with leading axis K (one minibatch per inner step).
    Returns (params_K, new_dual, z, grads_seq|None).
    """

    def body(carry, inp):
        params, rng_ = carry
        batch, k = inp
        rng_, sub = jax.random.split(rng_)
        grads = loss_grad_fn(params, batch, sub)
        new_params = local_step(params, grads, dual, anchor,
                                lr=hp.lr, lam=hp.lam, use_kernel=hp.use_kernel)
        out = grads if record_grads else None
        return (new_params, rng_), out

    ks = jnp.arange(K)
    (params_K, _), grads_seq = jax.lax.scan(body, (anchor, rng), (batches, ks))
    new_dual = dual_update(dual, params_K, anchor, lam=hp.lam)
    z = message(params_K, dual, lam=hp.lam)
    return params_K, new_dual, z, grads_seq
