"""Gossip/mixing matrices for decentralized federated learning.

Implements the communication topologies of the paper (Figure 1):
Ring, Grid (2-D torus), Exponential, Fully-connected, and the
"Random" time-varying topology used in Sec. 5.2 / 5.4, plus the
Definition-1 properties (symmetry, double stochasticity, null-space,
spectral bounds) and the spectral gap ``1 - psi``.

Beyond the paper's symmetric setting, the *directed* topologies
(``dring``, ``drandom``) model one-directional links (the ADFL setting
of arXiv:2310.05093).  Their matrices are column stochastic — each
sender splits its mass over its out-neighbours — and are only valid
under the push-sum transport (``repro.core.comm.PushSumTransport``),
which carries the weight correction that recovers the true average.

All matrices are plain ``numpy`` float64 on the host — they are tiny
(m x m) and are consumed either by the dense-mixing einsum or to derive
the neighbor lists for the ``ppermute`` mixing path.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

TOPOLOGIES = ("ring", "grid", "exp", "full", "random")
DIRECTED_TOPOLOGIES = ("dring", "drandom")


def _check_m(m: int) -> None:
    if m < 2:
        raise ValueError(f"gossip needs at least 2 clients, got m={m}")


# ---------------------------------------------------------------------------
# Adjacency construction (excluding self loops)
# ---------------------------------------------------------------------------

def ring_adjacency(m: int) -> np.ndarray:
    """Each client talks to its two ring neighbours (1 for m==2)."""
    _check_m(m)
    adj = np.zeros((m, m), dtype=bool)
    for i in range(m):
        adj[i, (i + 1) % m] = True
        adj[i, (i - 1) % m] = True
    np.fill_diagonal(adj, False)
    return adj


def grid_adjacency(m: int) -> np.ndarray:
    """2-D torus grid.  Requires m = r*c with r,c >= 2 (near-square)."""
    _check_m(m)
    r = int(np.floor(np.sqrt(m)))
    while m % r != 0:
        r -= 1
    c = m // r
    if r == 1:  # degenerate grid -> ring
        return ring_adjacency(m)
    adj = np.zeros((m, m), dtype=bool)

    def nid(i: int, j: int) -> int:
        return (i % r) * c + (j % c)

    for i in range(r):
        for j in range(c):
            u = nid(i, j)
            for v in (nid(i + 1, j), nid(i - 1, j), nid(i, j + 1), nid(i, j - 1)):
                if v != u:
                    adj[u, v] = True
                    adj[v, u] = True
    return adj


def exp_adjacency(m: int) -> np.ndarray:
    """Exponential graph: i connects to i +/- 2^k (mod m)."""
    _check_m(m)
    adj = np.zeros((m, m), dtype=bool)
    for i in range(m):
        k = 0
        while (1 << k) < m:
            j = (i + (1 << k)) % m
            if j != i:
                adj[i, j] = True
                adj[j, i] = True
            k += 1
    return adj


def full_adjacency(m: int) -> np.ndarray:
    _check_m(m)
    adj = np.ones((m, m), dtype=bool)
    np.fill_diagonal(adj, False)
    return adj


def random_adjacency(m: int, degree: int, seed: int) -> np.ndarray:
    """Random symmetric graph where each node has ~``degree`` neighbours.

    Used for the paper's time-varying "Random" topology (Sec. 5.4: each
    client communicates with 10 randomly selected neighbours each round).
    A fresh ``seed`` per round gives the time-varying behaviour.  The
    graph is made connected by overlaying a ring.
    """
    _check_m(m)
    degree = min(degree, m - 1)
    rng = np.random.default_rng(seed)
    adj = ring_adjacency(m)  # connectivity backbone
    for i in range(m):
        extra = max(degree - int(adj[i].sum()), 0)
        if extra <= 0:
            continue
        candidates = np.flatnonzero(~adj[i])
        candidates = candidates[candidates != i]
        if candidates.size == 0:
            continue
        pick = rng.choice(candidates, size=min(extra, candidates.size), replace=False)
        adj[i, pick] = True
        adj[pick, i] = True
    return adj


def directed_ring_adjacency(m: int) -> np.ndarray:
    """One-directional ring: client i receives only from i-1 (mod m).

    Convention (matching the receive-weight convention of the symmetric
    matrices): ``adj[i, j]`` is True iff there is a link j -> i.
    """
    _check_m(m)
    adj = np.zeros((m, m), dtype=bool)
    for i in range(m):
        adj[i, (i - 1) % m] = True
    return adj


def directed_random_adjacency(m: int, degree: int, seed: int) -> np.ndarray:
    """Random digraph: a directed-ring backbone (strong connectivity) plus
    ~``degree`` extra one-directional in-edges per node.  Deliberately NOT
    symmetrized — out-degrees are unequal, so the column-stochastic matrix
    is not doubly stochastic and plain averaging would be biased."""
    _check_m(m)
    degree = min(degree, m - 1)
    rng = np.random.default_rng(seed)
    adj = directed_ring_adjacency(m)
    for i in range(m):
        extra = max(degree - int(adj[i].sum()), 0)
        if extra <= 0:
            continue
        candidates = np.flatnonzero(~adj[i])
        candidates = candidates[candidates != i]
        if candidates.size == 0:
            continue
        pick = rng.choice(candidates, size=min(extra, candidates.size),
                         replace=False)
        adj[i, pick] = True           # j -> i only; no reverse edge
    return adj


def column_stochastic_weights(adj: np.ndarray) -> np.ndarray:
    """Push-sum weights for a digraph: sender j splits its mass equally
    over its out-neighbours and itself, so every *column* sums to 1.

    ``adj[i, j]`` means j -> i.  ``P[i, j] = 1 / (1 + outdeg(j))`` for
    each out-edge, with the same share kept on the diagonal."""
    adj = adj.copy()
    np.fill_diagonal(adj, False)
    outdeg = adj.sum(axis=0)                       # receivers of column j
    p = adj.astype(np.float64) / (outdeg + 1.0)[None, :]
    np.fill_diagonal(p, 1.0 / (outdeg + 1.0))
    return p


def validate_column_stochastic(p: np.ndarray, atol: float = 1e-9) -> None:
    """The push-sum requirement: nonnegative with unit column sums
    (mass conservation — Σ_i of what j sends equals what j had)."""
    m = p.shape[0]
    if p.shape != (m, m):
        raise ValueError("gossip matrix must be square")
    if np.any(p < -atol) or np.any(p > 1 + atol):
        raise ValueError("gossip weights must lie in [0, 1]")
    if not np.allclose(p.sum(axis=0), 1.0, atol=1e-7):
        raise ValueError("push-sum gossip matrix must be column-stochastic")


def as_column_stochastic(w: np.ndarray) -> np.ndarray:
    """Coerce a gossip matrix to the push-sum (column-stochastic) form.

    Column-stochastic input passes through; a merely row-stochastic input
    is transposed — the same directed graph re-expressed in the sender
    convention ("who I push to" instead of "who I listen to").  Doubly
    stochastic matrices are both, so every symmetric topology works under
    push-sum unchanged."""
    w = np.asarray(w, dtype=np.float64)
    if np.allclose(w.sum(axis=0), 1.0, atol=1e-7):
        return w
    if np.allclose(w.sum(axis=1), 1.0, atol=1e-7):
        return w.T
    raise ValueError("push-sum needs a row- or column-stochastic matrix")


def mask_and_renormalize_columns(p: np.ndarray, active: np.ndarray) -> np.ndarray:
    """Column-stochastic analogue of ``mask_and_renormalize``: edges that
    touch an inactive client are removed and the lost mass returns to the
    *sender's* diagonal, so every column still sums to 1 (push-sum mass
    conservation) and inactive clients neither send nor receive — their
    row and column collapse to identity."""
    p = np.asarray(p, dtype=np.float64)
    active = np.asarray(active, dtype=bool)
    if active.shape != (p.shape[0],):
        raise ValueError(
            f"active mask shape {active.shape} does not match m={p.shape[0]}")
    pm = np.where(np.outer(active, active), p, 0.0)
    np.fill_diagonal(pm, 0.0)
    np.fill_diagonal(pm, 1.0 - pm.sum(axis=0))
    return pm


def adjacency(topology: str, m: int, *, degree: int = 10, seed: int = 0) -> np.ndarray:
    """(m, m) bool adjacency (no self loops) for a symmetric ``topology``
    from ``TOPOLOGIES``; ``degree``/``seed`` apply to "random" only."""
    if topology == "ring":
        return ring_adjacency(m)
    if topology == "grid":
        return grid_adjacency(m)
    if topology == "exp":
        return exp_adjacency(m)
    if topology == "full":
        return full_adjacency(m)
    if topology == "random":
        return random_adjacency(m, degree, seed)
    raise ValueError(f"unknown topology {topology!r}; expected one of {TOPOLOGIES}")


# ---------------------------------------------------------------------------
# Weights
# ---------------------------------------------------------------------------

def metropolis_weights(adj: np.ndarray) -> np.ndarray:
    """Metropolis–Hastings weights: symmetric, doubly stochastic, and
    satisfying Definition 1 for any connected undirected graph."""
    m = adj.shape[0]
    deg = adj.sum(axis=1)
    w = np.zeros((m, m), dtype=np.float64)
    for i in range(m):
        for j in np.flatnonzero(adj[i]):
            w[i, j] = 1.0 / (1.0 + max(deg[i], deg[j]))
    np.fill_diagonal(w, 1.0 - w.sum(axis=1))
    return w


def uniform_weights(adj: np.ndarray) -> np.ndarray:
    """w_ij = 1/(deg_max+1) for neighbours, rest on the diagonal."""
    deg_max = int(adj.sum(axis=1).max())
    w = adj.astype(np.float64) / (deg_max + 1)
    np.fill_diagonal(w, 1.0 - w.sum(axis=1))
    return w


@dataclasses.dataclass(frozen=True)
class GossipSpec:
    """A concrete gossip matrix plus its derived quantities."""

    topology: str
    matrix: np.ndarray          # (m, m) float64
    psi: float                  # max(|lambda_2|, |lambda_m|)

    @property
    def m(self) -> int:
        return self.matrix.shape[0]

    @property
    def spectral_gap(self) -> float:
        return 1.0 - self.psi

    def neighbor_offsets(self) -> list[int]:
        """Ring-relative offsets j-i (mod m) with nonzero weight, excluding 0.

        Only meaningful for shift-invariant (circulant) topologies —
        ring/exp/full — where every client has the same offset pattern.
        Used by the collective_permute mixing path.
        """
        m = self.m
        offsets: set[int] = set()
        for i in range(m):
            for j in np.flatnonzero(self.matrix[i] > 0):
                if j != i:
                    offsets.add((j - i) % m)
        return sorted(offsets)

    def is_circulant(self) -> bool:
        m = self.m
        row0 = self.matrix[0]
        for i in range(1, m):
            if not np.allclose(np.roll(row0, i), self.matrix[i]):
                return False
        return True

    def masked(self, active: np.ndarray) -> "GossipSpec":
        """Restrict this round's gossip to the ``active`` clients.

        Returns a new spec whose matrix is ``mask_and_renormalize`` of
        this one — inactive rows/columns collapse to identity (those
        clients hold their state) while the active subgraph keeps
        Definition-1 symmetry and double stochasticity.  ``psi`` is
        recomputed; a disconnected active subgraph yields psi == 1
        (zero spectral gap), which is the honest signal that gossip
        cannot mix across the partition this round.

        The psi recompute is an m x m eigendecomposition per call — the
        ``simulate`` round loop therefore applies ``mask_and_renormalize``
        directly and skips this; use ``masked`` when you want the spec's
        derived quantities, not on a hot path.
        """
        if self.topology in DIRECTED_TOPOLOGIES:
            raise ValueError(
                "masked() row-renormalizes, which breaks column "
                "stochasticity; directed specs are masked per round by "
                "comm.PushSumTransport.prepare (mask_and_renormalize_columns)")
        w = mask_and_renormalize(self.matrix, active)
        return GossipSpec(topology=self.topology, matrix=w, psi=spectral_psi(w))


def spectral_psi(w: np.ndarray) -> float:
    """psi = max(|lambda_2|, |lambda_m|) of the symmetrized matrix — the
    paper's mixing constant; the spectral gap is ``1 - psi``."""
    eig = np.linalg.eigvalsh((w + w.T) / 2.0)
    eig = np.sort(np.abs(eig))[::-1]
    # largest eigenvalue is 1 (within fp error); psi is the second largest
    return float(eig[1]) if eig.size > 1 else 0.0


def make_gossip(topology: str, m: int, *, weights: str = "metropolis",
                degree: int = 10, seed: int = 0) -> GossipSpec:
    """Build the validated ``GossipSpec`` for ``topology`` over ``m``
    clients: Definition-1 (symmetric doubly-stochastic) matrices for the
    undirected ``TOPOLOGIES`` under the ``weights`` scheme
    ("metropolis" | "uniform"), column-stochastic push-sum matrices for
    the ``DIRECTED_TOPOLOGIES``; ``degree``/``seed`` shape the random
    graphs."""
    if topology in DIRECTED_TOPOLOGIES:
        # directed graphs take sender-normalized (column-stochastic)
        # weights regardless of the ``weights`` scheme; they are only
        # meaningful under the push-sum transport
        if topology == "dring":
            adj = directed_ring_adjacency(m)
        else:
            adj = directed_random_adjacency(m, degree, seed)
        p = column_stochastic_weights(adj)
        validate_column_stochastic(p)
        return GossipSpec(topology=topology, matrix=p, psi=spectral_psi(p))
    adj = adjacency(topology, m, degree=degree, seed=seed)
    if weights == "metropolis":
        w = metropolis_weights(adj)
    elif weights == "uniform":
        w = uniform_weights(adj)
    else:
        raise ValueError(f"unknown weight scheme {weights!r}")
    validate_gossip_matrix(w)
    return GossipSpec(topology=topology, matrix=w, psi=spectral_psi(w))


def mask_and_renormalize(w: np.ndarray, active: np.ndarray) -> np.ndarray:
    """Gossip matrix for a round where only ``active`` clients participate.

    Edges touching an inactive client are removed and the lost mass is
    returned to the diagonal, so every inactive row/column becomes the
    identity (the client holds its state) and every active row keeps its
    surviving off-diagonal weights with the self-weight absorbing the
    rest.  Off-diagonal entries are untouched among active pairs, so
    symmetry is preserved; rows sum to 1 by construction; symmetric +
    row-stochastic ⇒ doubly stochastic.  The result satisfies every
    ``validate_gossip_matrix`` property (Definition 1) restricted to the
    active subgraph — note eigenvalue 1 gains multiplicity for each
    inactive client, which is the correct spectrum for "those clients do
    not mix this round".
    """
    w = np.asarray(w, dtype=np.float64)
    active = np.asarray(active, dtype=bool)
    if active.shape != (w.shape[0],):
        raise ValueError(
            f"active mask shape {active.shape} does not match m={w.shape[0]}")
    wm = np.where(np.outer(active, active), w, 0.0)
    np.fill_diagonal(wm, 0.0)
    np.fill_diagonal(wm, 1.0 - wm.sum(axis=1))
    return wm


def validate_gossip_matrix(w: np.ndarray, atol: float = 1e-9) -> None:
    """Assert the Definition-1 properties of the paper."""
    m = w.shape[0]
    if w.shape != (m, m):
        raise ValueError("gossip matrix must be square")
    if np.any(w < -atol) or np.any(w > 1 + atol):
        raise ValueError("gossip weights must lie in [0, 1]")
    if not np.allclose(w, w.T, atol=atol):
        raise ValueError("gossip matrix must be symmetric")
    if not np.allclose(w.sum(axis=1), 1.0, atol=1e-7):
        raise ValueError("gossip matrix must be row-stochastic")
    eig = np.linalg.eigvalsh((w + w.T) / 2.0)
    if eig.min() <= -1 - atol or eig.max() > 1 + 1e-7:
        raise ValueError("gossip spectrum must satisfy I >= W > -I")
    # null{I-W} = span{1}: eigenvalue 1 must be simple for connected graphs
    ones = np.ones(m) / np.sqrt(m)
    if not np.allclose(w @ ones, ones, atol=1e-7):
        raise ValueError("1 must be an eigenvector of W")


def time_varying_specs(topology: str, m: int, rounds: int, *, degree: int = 10,
                       base_seed: int = 0, weights: str = "metropolis",
                       masks: Sequence[np.ndarray] | None = None
                       ) -> Sequence[GossipSpec]:
    """One GossipSpec per round.  Only 'random' varies in time by itself;
    passing per-round participation ``masks`` (e.g. from
    ``repro.core.participation.participation_schedule``) composes partial
    participation with any topology — each round's matrix is masked to
    that round's active clients via ``mask_and_renormalize``."""
    if topology in ("random", "drandom"):
        specs = [make_gossip(topology, m, weights=weights, degree=degree,
                             seed=base_seed + t) for t in range(rounds)]
    else:
        spec = make_gossip(topology, m, weights=weights)
        specs = [spec] * rounds
    if masks is None:
        return specs
    if len(masks) != rounds:
        raise ValueError(f"need one mask per round: {len(masks)} != {rounds}")
    return [s.masked(a) for s, a in zip(specs, masks)]


# ---------------------------------------------------------------------------
# Two-tier hierarchy (transport="hier"): clusters, heads, per-tier matrices
# ---------------------------------------------------------------------------

def resolve_clusters(m: int, clusters: int = 0) -> int:
    """Resolve ``DFLConfig.clusters`` for ``m`` clients: 0 picks the
    balanced heuristic ``~sqrt(m)`` (capped to [1, m])."""
    _check_m(m)
    if clusters < 0 or clusters > m:
        raise ValueError(f"clusters must be in [0, m={m}], got {clusters}")
    if clusters:
        return clusters
    return max(1, min(m, int(round(np.sqrt(m)))))


def cluster_labels(m: int, clusters: int) -> np.ndarray:
    """Contiguous near-equal blocks: client ``i`` belongs to cluster
    ``i * clusters // m`` (sizes differ by at most one)."""
    clusters = resolve_clusters(m, clusters)
    return (np.arange(m) * clusters) // m


def cluster_heads(labels: np.ndarray) -> np.ndarray:
    """First member of each cluster — the node carrying the inter-tier
    edges (and the fast hub under the cluster-aware network preset)."""
    n = int(labels.max()) + 1
    return np.array([int(np.flatnonzero(labels == c)[0]) for c in range(n)])


def hier_tier_matrices(m: int, clusters: int = 0,
                       *, weights: str = "metropolis"
                       ) -> tuple[np.ndarray, np.ndarray]:
    """The two tier matrices of the hierarchical transport.

    ``w_intra``: dense gossip inside each cluster (complete graph per
    contiguous block).  ``w_inter``: sparse ring over the cluster heads;
    every non-head row is the identity.  Both are Definition-1
    (symmetric, doubly stochastic), so their per-round composition
    ``w_inter @ w_intra`` preserves the population average exactly and
    each tier can be masked/robust-wrapped like any flat gossip matrix.
    """
    labels = cluster_labels(m, clusters)
    eye = np.eye(m, dtype=bool)
    intra_adj = (labels[:, None] == labels[None, :]) & ~eye
    w_intra = (metropolis_weights(intra_adj) if weights == "metropolis"
               else uniform_weights(intra_adj))
    heads = cluster_heads(labels)
    inter_adj = np.zeros((m, m), dtype=bool)
    if heads.size == 2:
        inter_adj[heads[0], heads[1]] = inter_adj[heads[1], heads[0]] = True
    elif heads.size > 2:
        for k, h in enumerate(heads):
            nxt = heads[(k + 1) % heads.size]
            inter_adj[h, nxt] = inter_adj[nxt, h] = True
    w_inter = (metropolis_weights(inter_adj) if weights == "metropolis"
               else uniform_weights(inter_adj))
    validate_gossip_matrix(w_intra)
    validate_gossip_matrix(w_inter)
    return w_intra, w_inter
