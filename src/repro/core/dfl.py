"""Round composition for decentralized federated learning.

The same ``train_round`` drives three execution substrates:

* single-device simulation (clients = a vmapped leading axis) — used for
  the faithful reproduction of the paper's experiments;
* one TPU pod: client axis sharded over the mesh ``data`` axis, each
  client's replica tensor-parallel over ``model``;
* multi-pod: as above, with the per-client batch data-parallel over ``pod``.

State layout: every leaf carries a leading client axis of size ``m``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core import (comm as comm_lib, sam, solvers as solvers_lib,
                        threat as threat_lib)
from repro.core.gossip import DIRECTED_TOPOLOGIES, GossipSpec
from repro.core.network import (NetworkModel, make_network, network_names)
from repro.core.participation import ParticipationSpec

PyTree = Any

# The paper's six decentralized algorithms.  The source of truth for what
# is runnable is the solver registry (``solvers.SOLVERS``): anything
# registered under the "dfl" scope — including algorithms added from user
# code via ``solvers.register_solver`` — is accepted by ``DFLConfig``.
ALGORITHMS = ("dfedadmm", "dfedadmm_sam", "dpsgd", "dfedavg", "dfedavgm",
              "dfedsam")


@dataclasses.dataclass(frozen=True)
class DFLConfig:
    algorithm: str = "dfedadmm"
    m: int = 16                  # number of clients
    K: int = 5                   # local iterations per round
    lam: float = 0.1             # ADMM penalty
    lr: float = 0.1              # local learning rate eta_l
    lr_decay: float = 0.998      # per-round decay (paper Sec. 5.1)
    rho: float = 0.1             # SAM radius for *_sam algorithms
    momentum: float = 0.9        # DFedAvgM
    weight_decay: float = 5e-4   # SGD baselines only (paper: not for ADMM)
    topology: str = "random"
    weights: str = "metropolis"
    degree: int = 10             # neighbours for the random topology
    transport: str = ""          # "dense" | "ppermute" | "pushsum" |
                                 # "hier" ("" resolves to "dense")
    codec: str = "identity"      # wire codec: "identity" | "int8" |
                                 # "topk" | "randk"
    codec_bits: int = 8          # int8 codec: bits per value (2..8)
    codec_k: int = 64            # topk/randk codecs: kept entries per leaf
    use_kernel: Any = False      # fused Pallas kernels: True = solver
                                 # inner update AND codec; "solver" /
                                 # "comm" select one side only
    microbatches: int = 1        # grad-accumulation splits per inner step
                                 # (exact for SGD; SAM perturbs per split)
    participation: ParticipationSpec = ParticipationSpec()
                                 # partial-participation scenario; the
                                 # default (full, no dropout/stragglers)
                                 # takes the exact paper code path
    network: Any = None          # network cost model: a preset name from
                                 # repro.core.network.NETWORKS, a
                                 # NetworkModel, or None (no wall-clock
                                 # modeling; history has no "sim_time")
    execution: str = "sync"      # "sync" = bulk-synchronous rounds (the
                                 # paper's Alg. 1); "async" = the event-
                                 # driven engine (repro.core.async_engine)
                                 # where each client gossips when its
                                 # modeled compute + transfer finishes
    tick_s: float = 0.0          # async: seconds of virtual time per
                                 # batched tick (one jitted computation)
    max_staleness: int = 4       # async: a neighbour's buffered iterate
                                 # older than this many ticks is masked
                                 # out of the mix (0 = only same-tick
                                 # publications are mixed)
    threat: Any = None           # adversarial scenario: a
                                 # repro.core.threat.ThreatSpec (seeded
                                 # Byzantine clients perturbing their
                                 # outgoing messages) or None — the
                                 # default builds the exact unthreatened
                                 # round, bit for bit
    robust: str = "mean"         # robust mixing: "mean" (plain gossip,
                                 # the unwrapped transport) or a
                                 # RobustAggregator name ("trimmed_mean",
                                 # "median", "krum", or registered)
    robust_trim: float = 0.25    # trimmed_mean: fraction trimmed per
                                 # side; krum: assumed Byzantine fraction
                                 # per neighbourhood
    dp_clip: float = 1.0         # dp codec: per-client L2 clip bound
    dp_noise: float = 0.0        # dp codec: noise multiplier (noise std
                                 # = dp_noise * dp_clip)
    n_virtual: int = 0           # cohort virtualization: total virtual
                                 # population; 0 = every client is device-
                                 # resident (the dense paper path). When
                                 # > 0, ``m`` is the hot-cohort size and
                                 # the cold population lives in a host-
                                 # side ClientStore (repro.core.cohort)
    clusters: int = 0            # two-tier hierarchy: number of clusters
                                 # for transport="hier" (0 resolves to a
                                 # heuristic ~sqrt(m)); also makes the
                                 # hub-and-spoke network preset cluster-
                                 # aware (one fast hub per cluster)
    adapt_mu: float = 10.0       # dfedadmm_adaptive: residual-imbalance
                                 # factor that triggers a penalty
                                 # rebalance (defaults mirror
                                 # solvers.AdaptiveADMMSolver.MU/TAU/
                                 # BOUND, so the default config is the
                                 # pre-sweep demo bit for bit)
    adapt_tau: float = 2.0       # dfedadmm_adaptive: multiplicative
                                 # lam_scale update per rebalance
    adapt_bound: float = 8.0     # dfedadmm_adaptive: lam_scale clipped
                                 # to [1/bound, bound]

    def __post_init__(self):
        if self.algorithm not in solvers_lib.solver_names("dfl"):
            raise ValueError(
                f"unknown algorithm {self.algorithm!r}; registered DFL "
                f"solvers: {solvers_lib.solver_names('dfl')}")
        eff = self.transport or "dense"
        if eff not in comm_lib.TRANSPORTS:
            raise ValueError(
                f"unknown transport {eff!r}; expected one of "
                f"{comm_lib.TRANSPORTS}")
        object.__setattr__(self, "transport", eff)
        if self.codec not in comm_lib.codec_names():
            raise ValueError(
                f"unknown codec {self.codec!r}; expected one of "
                f"{comm_lib.codec_names()}")
        if not 2 <= self.codec_bits <= 8:
            raise ValueError(f"codec_bits must be in [2, 8], "
                             f"got {self.codec_bits}")
        if self.codec_k < 1:
            raise ValueError(f"codec_k must be >= 1, got {self.codec_k}")
        if self.use_kernel not in (True, False, "comm", "solver"):
            raise ValueError(
                f"use_kernel must be a bool, 'comm', or 'solver', "
                f"got {self.use_kernel!r}")
        # adversarial/privacy layer (repro.core.threat): fail at config
        # construction with a clear message, never inside jit
        if self.threat is not None and not isinstance(
                self.threat, threat_lib.ThreatSpec):
            raise ValueError(
                "DFLConfig.threat must be a repro.core.threat.ThreatSpec "
                f"(or None), got {type(self.threat).__name__}: "
                f"{self.threat!r}")
        if self.robust not in threat_lib.aggregator_names():
            raise ValueError(
                f"unknown robust aggregator {self.robust!r}; expected one "
                f"of {threat_lib.aggregator_names()}")
        if not 0.0 <= self.robust_trim < 0.5:
            raise ValueError(
                "robust_trim is a per-side trim / Byzantine fraction and "
                f"must be in [0, 0.5), got {self.robust_trim}")
        if not self.dp_clip > 0.0:
            raise ValueError(
                f"dp_clip must be > 0 (per-client L2 clip bound), "
                f"got {self.dp_clip}")
        if self.dp_noise < 0.0:
            raise ValueError(
                f"dp_noise must be >= 0 (noise multiplier), "
                f"got {self.dp_noise}")
        if self.topology in DIRECTED_TOPOLOGIES and eff != "pushsum":
            raise ValueError(
                f"directed topology {self.topology!r} is only sound under "
                "transport='pushsum' (plain mixing with a non-doubly-"
                "stochastic matrix converges to a biased average)")
        if self.network is not None and not isinstance(
                self.network, NetworkModel):
            if self.network not in network_names():
                raise ValueError(
                    f"unknown network preset {self.network!r}; expected a "
                    f"NetworkModel or one of {network_names()}")
        if self.participation.mode == "deadline" and self.network is None:
            raise ValueError(
                "participation mode 'deadline' is driven by the network "
                "cost model: set DFLConfig.network to a preset from "
                f"{network_names()} (or a NetworkModel)")
        if self.execution not in ("sync", "async"):
            raise ValueError(
                f"execution must be 'sync' or 'async', got {self.execution!r}")
        if self.execution == "async":
            if self.network is None:
                raise ValueError(
                    "execution='async' schedules gossip events from the "
                    "network cost model: set DFLConfig.network to a preset "
                    f"from {network_names()} (or a NetworkModel)")
            if self.tick_s <= 0.0:
                raise ValueError(
                    "execution='async' needs tick_s > 0 (seconds of virtual "
                    f"time batched into one jitted tick), got {self.tick_s}")
            if self.max_staleness < 0:
                raise ValueError(
                    f"max_staleness must be >= 0, got {self.max_staleness}")
            if self.participation.mode == "deadline":
                raise ValueError(
                    "execution='async' subsumes the deadline mode: slow "
                    "clients tick late instead of being dropped — use a "
                    "sampling participation mode (or the default) with "
                    "async execution")
        if self.n_virtual < 0:
            raise ValueError(
                f"n_virtual must be >= 0, got {self.n_virtual}")
        if self.n_virtual and self.n_virtual < self.m:
            raise ValueError(
                f"n_virtual={self.n_virtual} is the total virtual "
                f"population and must be >= m={self.m} (the hot-cohort "
                "size); set n_virtual=0 for a fully device-resident run")
        if self.clusters < 0:
            raise ValueError(
                f"clusters must be >= 0, got {self.clusters}")
        if self.clusters > self.m:
            raise ValueError(
                f"clusters={self.clusters} exceeds m={self.m}: every "
                "cluster needs at least one cohort slot")
        if self.adapt_mu <= 0.0 or self.adapt_tau <= 1.0 \
                or self.adapt_bound < 1.0:
            raise ValueError(
                "adaptive-penalty sweep needs adapt_mu > 0, "
                "adapt_tau > 1, adapt_bound >= 1; got "
                f"adapt_mu={self.adapt_mu}, adapt_tau={self.adapt_tau}, "
                f"adapt_bound={self.adapt_bound}")

    def make_solver(self) -> "solvers_lib.LocalSolver":
        """The LocalSolver this config resolves to (algorithm facts like
        ``is_admm`` / ``sam_rho`` live on the solver object now)."""
        return solvers_lib.make_solver(self)

    def make_network_model(self, seed: int = 0) -> NetworkModel | None:
        """The NetworkModel this config resolves to: a preset name is
        built for ``m`` clients with ``seed``, an explicit NetworkModel
        passes through (after an m check), None stays None."""
        if self.network is None:
            return None
        return make_network(self.network, self.m, seed=seed,
                            hubs=self.clusters)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class DFLState:
    params: PyTree               # (m, ...) per leaf
    solver: PyTree               # solver-owned per-client state allocated by
                                 # LocalSolver.init_state: {"dual": ...} for
                                 # the ADMM family, {"momentum": ...} for
                                 # DFedAvgM, None for the stateless SGD
                                 # solvers — nothing is allocated for buffers
                                 # an algorithm does not use
    rng: jax.Array               # (m, 2) per-client PRNG keys
    round: jax.Array             # scalar int32
    comm: PyTree = None          # communication state (comm.init_comm_state):
                                 # push-sum weights / codec residuals /
                                 # the tracking buffer of a variance-
                                 # reduction solver ("track"); None for
                                 # the stateless seed configuration


def init_state(params_single: PyTree, cfg: DFLConfig, seed: int = 0) -> DFLState:
    """Broadcast one parameter pytree to m identical clients (paper: common
    init x^0); the solver allocates its own state (zero duals g_hat^{-1}
    for the ADMM family, nothing for stateless solvers)."""
    m = cfg.m
    stacked = jax.tree.map(lambda x: jnp.broadcast_to(x[None], (m,) + x.shape),
                           params_single)
    solver = solvers_lib.make_solver(cfg)
    keys = jax.random.split(jax.random.PRNGKey(seed), m)
    return DFLState(params=stacked, solver=solver.init_state(cfg, stacked),
                    rng=keys, round=jnp.zeros((), jnp.int32),
                    comm=comm_lib.init_comm_state(cfg, stacked))


def consensus_distance(params: PyTree) -> jax.Array:
    """mean_i || x_i - x_bar ||^2 — the model-inconsistency metric."""
    def leaf(x):
        xb = jnp.mean(x, axis=0, keepdims=True)
        return jnp.sum(jnp.square((x - xb).astype(jnp.float32)))
    total = sum(jax.tree.leaves(jax.tree.map(leaf, params)))
    m = jax.tree.leaves(params)[0].shape[0]
    return total / m


def mean_params(params: PyTree) -> PyTree:
    """x_bar — the evaluation model (paper outputs averaged parameters)."""
    return jax.tree.map(lambda x: jnp.mean(x, axis=0), params)


# ---------------------------------------------------------------------------
# Round builders
# ---------------------------------------------------------------------------

def make_local_phase(loss_fn: Callable[[PyTree, Any, jax.Array], jax.Array],
                     cfg: DFLConfig,
                     solver: "solvers_lib.LocalSolver | None" = None,
                     *, masked: bool, per_client_lr: bool = False):
    """Build the vmapped K-local-steps phase shared by the synchronous
    round (:func:`make_train_round`) and the async tick
    (``repro.core.async_engine``)::

        local_phase(params, sstate, batches, rngs, lr_t[, active, steps])
            -> (params_K, new_sstate, z, losses)

    All inputs/outputs carry the leading (m,) client axis except ``lr_t``,
    which is a scalar broadcast to every client by default and a
    per-client (m,) vector with ``per_client_lr=True`` (the async engine
    decays each client's rate by *its own* completed round count).  With
    ``masked=True`` the phase takes the per-round ``(active, steps)``
    arrays and gates every per-step quantity through ``jnp.where`` —
    inactive clients freeze, stragglers stop after ``steps_i`` iterations
    — keeping one fixed-shape jitted computation; at full participation
    the masked path is bit-identical to the unmasked one (pinned since
    the participation PR).
    """
    if solver is None:
        solver = solvers_lib.make_solver(cfg)

    loss_and_grad = sam.sam_value_and_grad(
        loss_fn, solver.sam_rho,
        use_kernel=cfg.use_kernel is True or cfg.use_kernel == "solver")

    if cfg.microbatches > 1:
        inner_lg = loss_and_grad

        def loss_and_grad(params, batch, rng):  # noqa: F811
            """Gradient accumulation over microbatch splits of the inner
            step's minibatch — mathematically identical to the full-batch
            gradient (mean of means over equal splits), but activations
            for only one microbatch are ever live.  The f32 accumulator
            also *improves* on bf16 single-shot summation numerics."""
            n = cfg.microbatches
            mb = jax.tree.map(
                lambda b: b.reshape((n, b.shape[0] // n) + b.shape[1:]),
                batch)

            def body(carry, mbatch):
                tot_l, tot_g = carry
                l, g = inner_lg(params, mbatch, rng)
                tot_g = jax.tree.map(
                    lambda t, gi: t + gi.astype(jnp.float32), tot_g, g)
                return (tot_l + l, tot_g), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (tl, tg), _ = jax.lax.scan(
                body, (jnp.zeros((), jnp.float32), zeros), mb)
            return tl / n, jax.tree.map(lambda g: g / n, tg)

    def _tree_where(pred, a, b):
        return jax.tree.map(lambda x, y: jnp.where(pred, x, y), a, b)

    def client_local(anchor, sstate, batches_k, rng, lr_t,
                     active_i=None, n_steps=None):
        """K local steps for ONE client -> (params_K, new_sstate, z, loss).

        One generic scan over ``solver.step`` for every registered
        algorithm — the seed's ``if cfg.is_admm / else`` fork lives in
        the solver objects now.  In the masked (partial-participation)
        path ``active_i`` is this client's scalar bool and ``n_steps``
        its local-iteration budget: iterations past ``n_steps`` are
        computed but discarded via ``jnp.where`` (keeping one
        fixed-shape scan), inactive clients freeze all state, and their
        gossip message degenerates to their own parameters so the
        identity row of the masked matrix holds them in place.
        """
        steps = solver.inner_steps(cfg.K)

        def body(carry, inp):
            params, st, rng_ = carry
            batch, k = inp if masked else (inp, None)
            rng_, sub = jax.random.split(rng_)
            l, g = loss_and_grad(params, batch, sub)
            new_params, new_st = solver.step(params, g, st, anchor, lr_t)
            if masked:
                take = k < n_steps
                new_params = _tree_where(take, new_params, params)
                new_st = _tree_where(take, new_st, st)
                l = jnp.where(take, l, 0.0)
            return (new_params, new_st, rng_), l

        bk = batches_k if steps == cfg.K else \
            jax.tree.map(lambda b: b[:steps], batches_k)
        xs = (bk, jnp.arange(steps)) if masked else bk
        (params_K, st_K, _), losses = jax.lax.scan(
            body, (anchor, sstate, rng), xs)
        new_sstate, z = solver.finalize(params_K, st_K, anchor, lr_t)
        if masked:
            # an inactive client (n_steps == 0) froze every per-step
            # quantity, but finalize may still move round-level state
            # (the ADMM dual update): gate it, and pin the message to
            # the anchor so the identity row of the masked matrix holds
            # the client in place
            new_sstate = _tree_where(active_i, new_sstate, sstate)
            z = _tree_where(active_i, z, anchor)
            # mean over the completed iterations, written as the static
            # mean rescaled by a runtime factor that is exactly 1.0 for
            # a fully participating client — reproducing the seed
            # path's jnp.mean bit for bit at full participation
            done = jnp.minimum(n_steps, steps).astype(jnp.float32)
            loss = jnp.mean(losses) * (jnp.float32(steps)
                                       / jnp.maximum(done, 1.0))
        else:
            loss = jnp.mean(losses)
        return params_K, new_sstate, z, loss

    lr_axis = 0 if per_client_lr else None
    if masked:
        vm = jax.vmap(client_local, in_axes=(0, 0, 0, 0, lr_axis, 0, 0))
    else:
        vm = jax.vmap(client_local, in_axes=(0, 0, 0, 0, lr_axis))

    def local_phase(params, sstate, batches, rngs, lr_t,
                    active=None, steps=None):
        if masked:
            return vm(params, sstate, batches, rngs, lr_t, active, steps)
        return vm(params, sstate, batches, rngs, lr_t)

    return local_phase


def make_train_round(loss_fn: Callable[[PyTree, Any, jax.Array], jax.Array],
                     cfg: DFLConfig,
                     spec: GossipSpec | None = None,
                     mesh: jax.sharding.Mesh | None = None,
                     client_axis: str = "data",
                     param_inner_specs: PyTree | None = None,
                     metrics: str = "full"):
    """Build ``round_fn(state, batches, plan) -> (state, metrics)``.

    * ``loss_fn(params_single, batch, rng) -> scalar`` — per-client loss.
    * ``batches`` leaves are shaped (m, K, ...): one minibatch per client
      per inner step (Alg. 1 line 5 samples fresh minibatches).
    * ``plan`` is this round's communication plan from
      ``Transport.prepare(spec_t, active)`` — for the dense and push-sum
      transports simply the (m, m) mixing matrix (supports the
      time-varying "random" topology), for ppermute ``None`` (static
      pattern from ``spec``) or the per-client gate arrays of a masked
      round.  A raw matrix is accepted everywhere the seed code passed
      one.  ``cfg.codec`` compresses the messages on the wire
      (stochastic-rounding quantization / top-k with error feedback); the
      codec residuals and the push-sum weights ride in ``state.comm``.
    * ``metrics``: "full" computes consensus distance + dual norm every
      round — a param-sized f32 cross-client all-reduce, fine for the
      simulation substrate but ~2x the gossip's own link bytes at 405B
      scale (and it drags the gossip permutes to f32 via convert
      hoisting).  "light" keeps only scalar telemetry; production runs
      sample full metrics every N rounds from the checkpoint instead.

    Participation: when ``cfg.participation`` is non-trivial the returned
    ``round_fn`` takes two extra per-round arrays,
    ``round_fn(state, batches, plan, active, steps)`` — ``active`` (m,)
    bool and ``steps`` (m,) int32 from
    ``participation.round_participation`` — and ``plan`` must come from
    ``Transport.prepare(spec_t, active)`` (which applies the
    mask-and-renormalize step for the transport).  The mask enters
    the vmapped local update via ``jnp.where`` (inactive clients freeze,
    stragglers stop after ``steps_i`` iterations), so the round stays one
    jitted computation with fixed shapes for any participation pattern.
    """
    if cfg.transport == "ppermute" and spec is None:
        raise ValueError("the ppermute transport needs a static GossipSpec")
    transport = comm_lib.make_transport(cfg, spec=spec, mesh=mesh,
                                        client_axis=client_axis,
                                        inner_specs=param_inner_specs)
    codec = comm_lib.make_codec(cfg)
    fused = comm_lib.can_fuse_dense(transport, codec)
    solver = solvers_lib.make_solver(cfg)
    masked = not cfg.participation.is_trivial
    local_phase = make_local_phase(loss_fn, cfg, solver, masked=masked)
    # adversarial layer: a seeded persistent adversary set perturbs its
    # outgoing messages inside the jitted round.  With no threat (or a
    # trivial one) nothing is built and the round is the exact
    # unthreatened computation.
    attack, adv_mask = None, None
    if cfg.threat is not None and not cfg.threat.is_trivial:
        adv_np = threat_lib.adversary_mask(cfg.threat, cfg.m)
        if adv_np.any():
            attack = threat_lib.make_attack(cfg.threat)
            adv_mask = jnp.asarray(adv_np)

    def round_fn(state: DFLState, batches: PyTree, plan,
                 active: jax.Array | None = None,
                 steps: jax.Array | None = None):
        lr_t = cfg.lr * (cfg.lr_decay ** state.round.astype(jnp.float32))
        rngs = jax.vmap(lambda k: jax.random.fold_in(k, state.round))(state.rng)
        sstate = state.solver
        if solver.tracks:
            # merge the gossip-carried tracking buffer into the solver
            # state under the reserved "track" key; finalize leaves the
            # outgoing track message in the same slot
            sstate = dict(state.solver, track=state.comm["track"])
        if masked:
            if active is None or steps is None:
                raise ValueError(
                    "cfg.participation is non-trivial: round_fn needs the "
                    "per-round (active, steps) arrays from "
                    "participation.round_participation")
            params_K, new_solver, z, losses = local_phase(
                state.params, sstate, batches, rngs, lr_t,
                active, steps)
        else:
            params_K, new_solver, z, losses = local_phase(
                state.params, sstate, batches, rngs, lr_t)
        track_msg = None
        if solver.tracks:
            new_solver = dict(new_solver)
            track_msg = new_solver.pop("track")

        if adv_mask is not None:
            # adversaries corrupt their OUTGOING message before the codec
            # sees it; a masked-out adversary transmits nothing this round
            # (and its z is the anchor the identity plan row must hold in
            # place), so the attack mask intersects the active mask
            atk_rng = jax.random.fold_in(
                jax.random.fold_in(state.rng[0], state.round), 0xBAD)
            adv_now = jnp.logical_and(adv_mask, active) if masked \
                else adv_mask
            z = attack.perturb(z, adv_now, atk_rng)

        wire_metrics = {}
        aux = state.comm if state.comm is not None else {}
        if codec.stateful:
            codec_rng = jax.random.fold_in(
                jax.random.fold_in(state.rng[0], state.round), 0x51AB3)
            if fused:
                # dense transport + quantize codec + use_kernel: the plan
                # IS the (m, m) matrix, so encode -> decode -> mix
                # collapses into one fused Pallas kernel per leaf (the
                # inactive-client gating included) — no f32 message
                # copies, no int8 wire tensor
                new_params, new_resid = codec.encode_mix_dense(
                    z, plan, aux.get("residual"), codec_rng,
                    active if masked else None)
                new_ps = aux.get("ps_weight")
            else:
                wire, new_resid = codec.encode(z, aux.get("residual"),
                                               codec_rng,
                                               active if masked else None)
                wire_metrics = codec.wire_metrics(wire)
                zhat = codec.decode(wire)
                if masked:
                    # an inactive client transmits nothing — its
                    # self-message must round-trip exactly so the identity
                    # row of the masked plan holds it in place
                    zhat = jax.tree.map(
                        lambda a, b: jnp.where(
                            active.reshape((cfg.m,) + (1,) * (a.ndim - 1)),
                            a, b),
                        zhat, z)
                new_params, new_ps = transport.mix(zhat, plan,
                                                   aux.get("ps_weight"))
        else:
            zhat, new_resid = z, None
            new_params, new_ps = transport.mix(zhat, plan,
                                               aux.get("ps_weight"))

        new_comm = state.comm
        if state.comm is not None:
            new_comm = dict(state.comm)
            if "ps_weight" in new_comm:
                new_comm["ps_weight"] = new_ps
            if "residual" in new_comm:
                new_comm["residual"] = new_resid
            if track_msg is not None:
                # the tracking variable rides the SAME contraction as z
                # (same plan, so a masked round's identity rows hold an
                # inactive client's buffered variate in place); the
                # push-sum weight update is owned by z's mix above —
                # discard the duplicate
                mixed_track, _ = transport.mix(track_msg, plan,
                                               aux.get("ps_weight"))
                new_comm["track"] = mixed_track

        if masked:
            af = active.astype(jnp.float32)
            # mean over active clients == static mean over all clients
            # rescaled by m/n_active; at full participation the scale is
            # exactly 1.0, so the metric matches the seed path bit for bit.
            # A round with no active clients (only reachable via an empty
            # schedule entry) has no loss measurement — report NaN, not a
            # spurious 0.0 that would read as perfect convergence.
            n_active = jnp.sum(af)
            mean_loss = jnp.mean(losses * af) * (
                jnp.float32(cfg.m) / jnp.maximum(n_active, 1.0))
            out_metrics = {
                "loss": jnp.where(n_active > 0, mean_loss, jnp.nan),
                "lr": lr_t,
                "participation": jnp.mean(af),
            }
        else:
            out_metrics = {"loss": jnp.mean(losses), "lr": lr_t}
        out_metrics.update(wire_metrics)
        if metrics == "full":
            out_metrics["consensus_sq"] = consensus_distance(new_params)
            d = solver.dual_tree(new_solver)
            out_metrics["dual_norm"] = sam.global_norm(d) if d is not None \
                else jnp.zeros((), jnp.float32)
        new_state = DFLState(params=new_params, solver=new_solver,
                             rng=state.rng,
                             round=state.round + 1, comm=new_comm)
        return new_state, out_metrics

    return round_fn


# ---------------------------------------------------------------------------
# Convenience simulation driver (single device, m clients via vmap)
# ---------------------------------------------------------------------------

def simulate(loss_fn, eval_fn, params_single: PyTree, cfg: DFLConfig,
             sample_batches: Callable[[int], PyTree], rounds: int,
             seed: int = 0, eval_every: int = 10, verbose: bool = False):
    """Run ``rounds`` rounds; returns (state, history dict of lists).

    ``sample_batches(t)`` -> leaves (m, K, ...)   (host-side data pipeline)
    ``eval_fn(params_single) -> dict`` evaluated on the client-mean model.

    ``cfg.participation`` selects the scenario: with the trivial default
    every client runs every round on the exact seed code path; otherwise
    the per-round mask from ``participation.round_participation`` gates
    the local updates, ``Transport.prepare`` restricts the round's plan
    to the active subgraph, and ``history["participation"]`` records the
    realized per-round active fraction.

    ``cfg.transport`` / ``cfg.codec`` select the communication layer
    (``repro.core.comm``); ``history["wire_bytes"]`` records the modeled
    uplink bytes per round (active clients x codec message size).  The
    ppermute transport compiles one static neighbour pattern, so it
    rejects the time-varying random topologies instead of silently
    reusing round 0's graph.

    ``cfg.network`` attaches the per-link cost model
    (``repro.core.network``): ``history["sim_time"]`` then records each
    round's modeled wall-clock (K x compute_s + the slowest active
    in-neighbour link for the codec's message size — the critical path).
    With ``participation.mode == "deadline"`` the model also *drives*
    participation: clients whose modeled transfer misses
    ``participation.deadline`` are masked exactly like sampled-out
    clients, through the same per-round (active, steps) arrays and
    masked plan — the round stays one jitted computation.
    """
    from repro.core.participation import participation_schedule
    from repro.core.gossip import time_varying_specs

    if cfg.n_virtual:
        # cohort virtualization: the cold population lives host-side,
        # only the m-slot hot cohort runs on device (repro.core.cohort;
        # handles execution="async" itself via per-cohort ticks)
        from repro.core.cohort import simulate_virtual
        return simulate_virtual(loss_fn, eval_fn, params_single, cfg,
                                sample_batches, rounds, seed=seed,
                                eval_every=eval_every, verbose=verbose)
    if cfg.execution == "async":
        from repro.core.async_engine import simulate_async
        return simulate_async(loss_fn, eval_fn, params_single, cfg,
                              sample_batches, rounds, seed=seed,
                              eval_every=eval_every, verbose=verbose)
    if cfg.transport == "ppermute" and cfg.topology in ("random", "drandom"):
        raise ValueError(
            f"topology={cfg.topology!r} draws a fresh non-circulant graph "
            "every round, but the ppermute transport compiles one static "
            "neighbour pattern and would silently gossip over round 0's "
            "graph forever; use transport='dense' for time-varying "
            "topologies")
    specs = time_varying_specs(cfg.topology, cfg.m, rounds,
                               degree=cfg.degree, base_seed=seed,
                               weights=cfg.weights)
    spec0 = specs[0]
    round_fn = jax.jit(make_train_round(loss_fn, cfg, spec=spec0))
    state = init_state(params_single, cfg, seed=seed)
    transport = comm_lib.make_transport(cfg, spec=spec0)
    codec = comm_lib.make_codec(cfg)
    bytes_per_client = codec.bytes_per_client(params_single)
    if solvers_lib.make_solver(cfg).tracks:
        # a tracking solver gossips a second, uncompressed param-sized
        # message per round; the wire accounting and the network cost
        # model both price it
        bytes_per_client += comm_lib.IdentityCodec().bytes_per_client(
            params_single)

    net = cfg.make_network_model(seed=seed)
    # only the deadline mode consumes per-round transfer times; other
    # participation modes ignore them, so don't draw the jitter for them
    transfer = None if net is None or \
        cfg.participation.mode != "deadline" else [
        net.transfer_times(s.matrix, bytes_per_client, t)
        for t, s in enumerate(specs)]

    trivial = cfg.participation.is_trivial
    sched = None if trivial else participation_schedule(
        cfg.participation, cfg.m, rounds, cfg.K, transfer_times=transfer)

    history: dict[str, list] = {"round": [], "loss": [], "lr": [],
                                "consensus_sq": [], "dual_norm": [],
                                "wire_bytes": [], "wall_us": []}
    if not trivial:
        history["participation"] = []
    if net is not None:
        history["sim_time"] = []
    for k in codec.metric_names():
        history[k] = []                 # e.g. dp codec clip-fraction rows
    eval_hist: dict[str, list] = {}
    for t in range(rounds):
        batches = sample_batches(t)
        t0 = time.perf_counter()
        if trivial:
            plan = transport.prepare(specs[t])
            state, metrics = round_fn(state, batches, plan)
            n_active = cfg.m
        else:
            rp = sched[t]
            plan = transport.prepare(specs[t], rp.active)
            state, metrics = round_fn(state, batches, plan,
                                      jnp.asarray(rp.active),
                                      jnp.asarray(rp.steps))
            n_active = int(rp.active.sum())
        jax.block_until_ready((state.params, metrics))
        # round 0 carries the jit compile; steady-state cost is the
        # median of wall_us[1:] (benchmarks.common.run_dfl reports that)
        history["wall_us"].append((time.perf_counter() - t0) * 1e6)
        if not trivial:
            history["participation"].append(float(metrics["participation"]))
        history["wire_bytes"].append(bytes_per_client * n_active)
        if net is not None:
            if cfg.participation.mode == "deadline":
                # price the realized receive times of the clients the
                # deadline decision kept IN the round: every included
                # client physically waited for all its in-links before
                # the decision (the min_active floor may force a
                # deadline-missing client in, and then *its* transfer is
                # the round's critical path).  Recomputing transfer over
                # the post-mask subgraph would silently drop the forced
                # client's slow in-links along with the masked senders.
                history["sim_time"].append(net.deadline_round_time(
                    transfer[t], sched[t].active, cfg.K))
            else:
                act = None if trivial else sched[t].active
                tiers = transport.sim_tiers(specs[t], act)
                if tiers is not None:
                    # multi-tier transports (hier) run their tiers
                    # sequentially: price the per-tier critical paths
                    history["sim_time"].append(net.tiered_round_time(
                        tiers, bytes_per_client, t, cfg.K, active=act))
                else:
                    history["sim_time"].append(net.round_time(
                        specs[t].matrix, bytes_per_client, t, cfg.K,
                        active=act))
        history["round"].append(t)
        for k in ("loss", "lr", "consensus_sq", "dual_norm") \
                + codec.metric_names():
            history[k].append(float(metrics[k]))
        if eval_fn is not None and ((t + 1) % eval_every == 0 or t == rounds - 1):
            ev = eval_fn(mean_params(state.params))
            eval_hist.setdefault("round", []).append(t)
            for k, v in ev.items():
                eval_hist.setdefault(k, []).append(float(v))
            if verbose:
                print(f"round {t+1:4d} loss={history['loss'][-1]:.4f} "
                      + " ".join(f"{k}={v[-1]:.4f}" for k, v in eval_hist.items()
                                 if k != "round"))
    history["eval"] = eval_hist
    return state, history
