"""Core library: the paper's contribution (DFedADMM / DFedADMM-SAM) plus
the gossip substrate and every baseline the paper compares against."""
from repro.core.admm import (ADMMHParams, client_round, dual_update, gamma,
                             gamma_k, lemma2_delta, lemma3_dual, local_step,
                             message)
from repro.core.dfl import (ALGORITHMS, DFLConfig, DFLState, consensus_distance,
                            init_state, make_train_round, mean_params, simulate)
from repro.core.gossip import (GossipSpec, TOPOLOGIES, adjacency, make_gossip,
                               mask_and_renormalize, metropolis_weights,
                               spectral_psi, time_varying_specs,
                               uniform_weights, validate_gossip_matrix)
from repro.core.participation import (ParticipationSpec, RoundParticipation,
                                      participation_schedule,
                                      round_participation)
from repro.core.mixing import mix, mix_dense, mix_ppermute, mix_ppermute_local
from repro.core.sam import global_norm, perturb, sam_grad_fn, sam_value_and_grad
from repro.core.baselines import (CFLConfig, CFLState, init_cfl_state,
                                  make_cfl_round, simulate_cfl)
