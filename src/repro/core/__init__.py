"""Core library: the paper's contribution (DFedADMM / DFedADMM-SAM) plus
the gossip substrate and every baseline the paper compares against."""
from repro.core.admm import (ADMMHParams, client_round, dual_update, gamma,
                             gamma_k, lemma2_delta, lemma3_dual, local_step,
                             message)
from repro.core.dfl import (ALGORITHMS, DFLConfig, DFLState, consensus_distance,
                            init_state, make_local_phase, make_train_round,
                            mean_params, simulate)
from repro.core.async_engine import (AsyncScheduler, TickEvents,
                                     VirtualScheduler, effective_matrix,
                                     make_tick_round, simulate_async)
from repro.core.cohort import ClientStore, simulate_virtual
from repro.core.gossip import (DIRECTED_TOPOLOGIES, GossipSpec, TOPOLOGIES,
                               adjacency, as_column_stochastic,
                               cluster_heads, cluster_labels,
                               column_stochastic_weights,
                               directed_ring_adjacency, hier_tier_matrices,
                               make_gossip, mask_and_renormalize,
                               mask_and_renormalize_columns,
                               metropolis_weights, resolve_clusters,
                               spectral_psi, time_varying_specs,
                               uniform_weights, validate_column_stochastic,
                               validate_gossip_matrix)
from repro.core.comm import (CODECS, TRANSPORTS, DenseTransport, Fp8Codec,
                             HierTransport, IdentityCodec, MessageCodec,
                             PpermuteTransport, PushSumTransport,
                             QuantizeCodec, RandKCodec, TopKCodec, Transport,
                             codec_names, init_comm_state, make_codec,
                             make_transport, register_codec)
from repro.core.network import (NETWORKS, NetworkModel, make_network,
                                network_names, register_network)
from repro.core.threat import (AGGREGATORS, ATTACKS, Attack, DPCodec,
                               KrumAggregator, MeanAggregator,
                               MedianAggregator, RobustAggregator,
                               RobustTransport, ThreatSpec,
                               TrimmedMeanAggregator, adversary_mask,
                               aggregator_names, attack_names,
                               make_aggregator, make_attack,
                               register_aggregator, register_attack)
from repro.core.participation import (ParticipationSpec, RoundParticipation,
                                      cohort_ids, participation_schedule,
                                      round_participation)
from repro.core.mixing import (mix, mix_dense, mix_ppermute,
                               mix_ppermute_local, mix_pushsum_ppermute,
                               mix_pushsum_ppermute_local)
from repro.core.sam import global_norm, perturb, sam_grad_fn, sam_value_and_grad
from repro.core.solvers import (SOLVERS, ADMMSolver, AdaptiveADMMSolver,
                                LocalSolver, MomentumSGDSolver, SGDSolver,
                                make_solver, register_solver, solver_names)
from repro.core.baselines import (CFLConfig, CFLState, init_cfl_state,
                                  make_cfl_round, simulate_cfl)
