"""Pluggable local-solver layer: the algorithm zoo behind one protocol.

The paper's contributions are *algorithms* — DFedADMM's dual-controlled
local solve and its SAM variant — yet the seed code hardcoded them as an
``if cfg.is_admm / else`` fork inside ``dfl.py:client_local`` and then
re-implemented the same inner loops a second time for the centralized
simulators in ``baselines.py``.  This module mirrors the comm-layer
design (``core/comm.py``): a small protocol, a registry, and one generic
round loop that works for every entry.

``LocalSolver`` — what one client does between gossip steps::

    sstate          = solver.init_state(cfg, stacked_params)   # (m, ...) or None
    params', st'    = solver.step(params, grad, st, anchor, lr)  # per inner iter
    st'', z         = solver.finalize(params_K, st', anchor, lr) # message to wire

* ``init_state`` allocates the solver-owned per-client state with a
  leading client axis (``DFLState.solver``).  Solvers that need nothing
  return ``None`` — no more dead parameter-sized zero buffers riding
  through every round (at 405B scale an unused momentum tree alone is a
  full parameter-sized allocation).
* ``step`` is one inner iterate given the already-computed (possibly
  SAM-perturbed) gradient.  Inside the round it runs under ``vmap``, so
  it sees ONE client's slice of the state.
* ``finalize`` turns the K-step result into the next round-start state
  and the gossip message ``z`` (Alg. 1 line 17 for ADMM; the plain
  parameters for the SGD family).

SAM is orthogonal to the solver: it only changes the gradient oracle,
so solvers expose ``sam_rho`` and the round loop builds
``sam.sam_value_and_grad`` once (``rho = 0`` is a plain gradient).

Variance reduction is orthogonal to the transport: solvers with
``tracks = True`` (SCAFFOLD's control variates, gradient tracking) own a
second gossip-carried buffer allocated by :meth:`LocalSolver.init_track`
and threaded through ``DFLState.comm["track"]``.  The round loop merges
the buffer into the solver state under the reserved key ``"track"``
before the local phase, pops the solver's outgoing track *message* from
the same key after ``finalize``, and sends it through the SAME transport
contraction as ``z`` (masked/participation-aware like the codec
residual) — so a tracking solver composes with every transport,
execution mode, and cohort layout without touching the round loop.

``SOLVERS`` maps algorithm names to ``(factory, scopes)``; ``scopes``
says which simulators may run it (``"dfl"`` — the gossip round in
``dfl.py``; ``"cfl"`` — the server round in ``baselines.py``).  Register
a new algorithm with :func:`register_solver` and it becomes selectable
through ``DFLConfig(algorithm=...)`` / the train CLI without touching
the round loop — e.g. ``dfedadmm_adaptive`` below (FedADMM-style,
arXiv:2204.03529) is a ~40-line solver, not a ``dfl.py`` surgery.

``use_kernel`` routes each solver's fused Pallas update through the same
interface: the ADMM inner iterate via ``kernels/admm_update.py`` and the
SGD-family update via the scale-add kernel in ``kernels/sam_scale.py``
(``ops.sgd_update``, scale = -lr).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core import admm, sam

PyTree = Any


class LocalSolver:
    """Protocol for one client's local optimization between gossip steps.

    Subclasses override :meth:`step` (required) plus any of the hooks;
    attributes:

    * ``name``     — registry name (set by :func:`make_solver`).
    * ``sam_rho``  — SAM radius for the gradient oracle (0 = plain).
    * ``is_admm``  — carries an ADMM dual variable (drives the
      ``dual_norm`` metric and the FedPD-style server aggregation).
    * ``tracks``   — owns a gossip-carried tracking buffer
      (``DFLState.comm["track"]``, allocated by :meth:`init_track`);
      inside :meth:`step`/:meth:`finalize` the buffer rides the solver
      state under the reserved key ``"track"``, and the value
      ``finalize`` leaves there is the client's outgoing track message.
    """

    name: str = ""
    sam_rho: float = 0.0
    is_admm: bool = False
    tracks: bool = False

    def init_state(self, cfg, stacked_params: PyTree) -> PyTree | None:
        """Solver state with a leading (m,) client axis, or None."""
        return None

    def init_track(self, cfg, stacked_params: PyTree) -> PyTree | None:
        """The gossip-carried tracking buffer (``tracks = True`` solvers
        only): a (m, ...)-stacked param-shaped pytree, zero-initialized
        so round 0 reduces to the uncorrected update."""
        return None

    def inner_steps(self, K: int) -> int:
        """Local iterations per round (D-PSGD does one)."""
        return K

    def step(self, params: PyTree, grads: PyTree, state: PyTree | None,
             anchor: PyTree, lr) -> tuple[PyTree, PyTree | None]:
        """One inner iterate for ONE client -> (params', state')."""
        raise NotImplementedError

    def finalize(self, params_K: PyTree, state: PyTree | None,
                 anchor: PyTree, lr) -> tuple[PyTree | None, PyTree]:
        """End-of-round hook for ONE client -> (state', message_z).
        ``lr`` is this round's (decayed) local learning rate — the
        variance-reduction family divides by it to turn the K-step
        displacement into a pseudo-gradient."""
        return state, params_K

    def dual_tree(self, state: PyTree | None) -> PyTree | None:
        """The ADMM dual variable inside ``state`` (telemetry), or None."""
        return None

    def state_specs(self, param_specs: PyTree, client_axis: str):
        """PartitionSpec pytree mirroring :meth:`init_state`'s structure
        (param-shaped buffers share the stacked param specs)."""
        return None


class SGDSolver(LocalSolver):
    """Plain (decentralized) SGD with weight decay: DFedAvg / DFedSAM /
    FedAvg / FedSAM, and D-PSGD via ``one_step``.  Stateless — no
    parameter-sized buffers are ever allocated."""

    def __init__(self, weight_decay: float = 0.0, rho: float = 0.0,
                 one_step: bool = False, use_kernel: bool = False):
        self.weight_decay = weight_decay
        self.sam_rho = rho
        self.one_step = one_step
        self.use_kernel = use_kernel

    def inner_steps(self, K: int) -> int:
        return 1 if self.one_step else K

    def _decayed(self, grads, params):
        wd = self.weight_decay
        if wd:
            return jax.tree.map(lambda gi, p: gi + wd * p, grads, params)
        return grads

    def _apply(self, params, upd, lr):
        if self.use_kernel:
            from repro.kernels import ops as kops
            return jax.tree.map(lambda p, u: kops.sgd_update(p, u, lr=lr),
                                params, upd)
        return jax.tree.map(
            lambda p, u: (p.astype(jnp.float32)
                          - lr * u.astype(jnp.float32)).astype(p.dtype),
            params, upd)

    def step(self, params, grads, state, anchor, lr):
        return self._apply(params, self._decayed(grads, params), lr), state


class MomentumSGDSolver(SGDSolver):
    """DFedAvgM: heavy-ball momentum on top of the SGD step.  The only
    SGD-family member that owns a parameter-sized buffer."""

    def __init__(self, momentum: float = 0.9, weight_decay: float = 0.0,
                 use_kernel: bool = False):
        super().__init__(weight_decay=weight_decay, use_kernel=use_kernel)
        self.momentum = momentum

    def init_state(self, cfg, stacked_params):
        return {"momentum": jax.tree.map(jnp.zeros_like, stacked_params)}

    def step(self, params, grads, state, anchor, lr):
        g = self._decayed(grads, params)
        new_mom = jax.tree.map(
            lambda mi, gi: (self.momentum * mi + gi).astype(mi.dtype),
            state["momentum"], g)
        return self._apply(params, new_mom, lr), {"momentum": new_mom}

    def state_specs(self, param_specs, client_axis):
        return {"momentum": param_specs}


class ADMMSolver(LocalSolver):
    """DFedADMM(-SAM) / FedPD: the dual-controlled local solve.

    State is the dual variable g_hat (Alg. 1).  ``message_dual`` selects
    which dual enters the wire message: DFedADMM sends
    ``x_K - lam * g_hat^{t-1}`` (the OLD dual, Alg. 1 line 17) while
    FedPD's server message uses the NEW dual (Eq. 5).
    """

    is_admm = True

    def __init__(self, lam: float, rho: float = 0.0,
                 use_kernel: bool = False, message_dual: str = "old"):
        if message_dual not in ("old", "new"):
            raise ValueError(f"message_dual must be 'old' or 'new', "
                             f"got {message_dual!r}")
        self.lam = lam
        self.sam_rho = rho
        self.use_kernel = use_kernel
        self.message_dual = message_dual

    def init_state(self, cfg, stacked_params):
        return {"dual": jax.tree.map(jnp.zeros_like, stacked_params)}

    def _lam(self, state):
        return self.lam

    def step(self, params, grads, state, anchor, lr):
        new_params = admm.local_step(params, grads, state["dual"], anchor,
                                     lr=lr, lam=self._lam(state),
                                     use_kernel=self.use_kernel)
        return new_params, state

    def finalize(self, params_K, state, anchor, lr):
        lam = self._lam(state)
        new_dual = admm.dual_update(state["dual"], params_K, anchor, lam=lam)
        src = new_dual if self.message_dual == "new" else state["dual"]
        z = admm.message(params_K, src, lam=lam)
        return dict(state, dual=new_dual), z

    def dual_tree(self, state):
        return state["dual"]

    def state_specs(self, param_specs, client_axis):
        return {"dual": param_specs}


class AdaptiveADMMSolver(ADMMSolver):
    """FedADMM-style per-client adaptive penalty (arXiv:2204.03529).

    Each client carries a scalar ``lam_scale`` multiplying the global
    penalty ``lam`` and rebalances it once per round from its residuals:
    with primal residual r = ||x_K - anchor|| (local drift this round)
    and dual magnitude d = lam_i * ||g_hat|| (the restoring force of the
    dual constraint), a client whose drift dominates (r > mu * d)
    tightens the penalty (lam_i /= tau — recall the penalty term is
    (x - anchor)^2 / 2lam, so smaller lam pulls harder) and one whose
    dual force dominates relaxes it (lam_i *= tau).  ``lam_scale`` is
    clipped to [1/bound, bound] so the solve stays in the regime the
    paper's lemmas assume (lr/lam < 1).
    """

    MU = 10.0       # rebalance only on an order-of-magnitude imbalance
    TAU = 2.0       # multiplicative update per rebalance
    BOUND = 8.0     # lam_scale stays in [1/BOUND, BOUND]

    def __init__(self, lam: float, rho: float = 0.0,
                 use_kernel: bool = False, message_dual: str = "old",
                 mu: float | None = None, tau: float | None = None,
                 bound: float | None = None):
        super().__init__(lam=lam, rho=rho, use_kernel=use_kernel,
                         message_dual=message_dual)
        # sweepable residual-balancing knobs; the class constants stay
        # the documented defaults (and what the tests pin against)
        self.mu = self.MU if mu is None else float(mu)
        self.tau = self.TAU if tau is None else float(tau)
        self.bound = self.BOUND if bound is None else float(bound)
        if self.mu <= 0 or self.tau <= 1.0 or self.bound < 1.0:
            raise ValueError(
                f"adaptive penalty needs mu > 0, tau > 1, bound >= 1; "
                f"got mu={self.mu}, tau={self.tau}, bound={self.bound}")

    def init_state(self, cfg, stacked_params):
        m = jax.tree.leaves(stacked_params)[0].shape[0]
        return {"dual": jax.tree.map(jnp.zeros_like, stacked_params),
                "lam_scale": jnp.ones((m,), jnp.float32)}

    def _lam(self, state):
        return self.lam * state["lam_scale"]

    def finalize(self, params_K, state, anchor, lr):
        new_state, z = super().finalize(params_K, state, anchor, lr)
        lam = self._lam(state)
        drift = jax.tree.map(lambda xk, a: xk - a, params_K, anchor)
        r = sam.global_norm(drift)
        d = lam * sam.global_norm(new_state["dual"])
        scale = state["lam_scale"]
        scale = jnp.where(r > self.mu * d, scale / self.tau,
                          jnp.where(d > self.mu * r, scale * self.tau,
                                    scale))
        scale = jnp.clip(scale, 1.0 / self.bound, self.bound)
        return dict(new_state, lam_scale=scale), z

    def state_specs(self, param_specs, client_axis):
        from jax.sharding import PartitionSpec as P
        return {"dual": param_specs, "lam_scale": P(client_axis)}


class ScaffoldSolver(SGDSolver):
    """SCAFFOLD-style control variates against client drift
    (arXiv:1910.06378, decentralized via the gossip contraction).

    Each client owns a control variate ``c_i`` (``state["cv"]``) and
    consumes the gossip-averaged global variate ``c_hat_i``
    (``DFLState.comm["track"]``, merged into the state as
    ``state["track"]`` by the round loop).  Every inner step applies the
    drift correction to the gradient::

        y <- y - lr * (g + c_hat_i - c_i)

    and ``finalize`` performs the SCAFFOLD option-II variate update from
    the K-step displacement d = (anchor - y_K) / (K * lr)::

        c_i+ = c_i - c_hat_i + d

    The client's outgoing track message is its NEW variate ``c_i+``; the
    transport mixes the messages exactly like ``z``, so each client's
    next ``c_hat_i`` is its neighbourhood average of the variates — the
    decentralized analogue of SCAFFOLD's server-held ``c``.  Under a
    doubly stochastic plan at full participation the sums of ``c_i`` and
    ``c_hat_i`` stay equal (zero at init), so the corrections sum to
    zero across clients every round (pinned in tests/test_property.py);
    with the variates at zero the update IS the plain SGD step.
    """

    tracks = True

    def __init__(self, weight_decay: float = 0.0, K: int = 1,
                 use_kernel: bool = False):
        super().__init__(weight_decay=weight_decay, use_kernel=use_kernel)
        self.K = K

    def init_state(self, cfg, stacked_params):
        return {"cv": jax.tree.map(jnp.zeros_like, stacked_params)}

    def init_track(self, cfg, stacked_params):
        return jax.tree.map(jnp.zeros_like, stacked_params)

    def step(self, params, grads, state, anchor, lr):
        g = self._decayed(grads, params)
        corrected = jax.tree.map(
            lambda gi, ch, c: gi + (ch.astype(gi.dtype) - c.astype(gi.dtype)),
            g, state["track"], state["cv"])
        return self._apply(params, corrected, lr), state

    def finalize(self, params_K, state, anchor, lr):
        inv = 1.0 / (jnp.float32(self.K) * lr)
        d = jax.tree.map(
            lambda a, y: ((a.astype(jnp.float32) - y.astype(jnp.float32))
                          * inv).astype(a.dtype),
            anchor, params_K)
        new_cv = jax.tree.map(lambda c, ch, di: (c - ch + di).astype(c.dtype),
                              state["cv"], state["track"], d)
        # the outgoing track message (the "track" slot the round loop
        # pops) is the fresh variate itself
        return {"cv": new_cv, "track": new_cv}, params_K

    def state_specs(self, param_specs, client_axis):
        return {"cv": param_specs}


class TrackingSolver(SGDSolver):
    """Gradient-tracking consistency solver (FedSpeed / DFedTrack style,
    cf. the consistency line of arXiv:2302.04083).

    The tracking variable ``t_i`` (``DFLState.comm["track"]``) estimates
    the population-average pseudo-gradient and is updated through the
    SAME gossip contraction as ``z``.  With ``d_i`` the client's own
    last pseudo-gradient (``state["d_prev"]``), every inner step replaces
    the local gradient's bias with the tracked global direction::

        y <- y - lr * (g - d_i + t_i)

    ``finalize`` computes this round's pseudo-gradient
    d_i+ = (anchor - y_K) / (K * lr) and emits the dynamic-average-
    consensus message ``t_i + d_i+ - d_i``; after mixing, summing over
    clients under any doubly stochastic plan gives the conservation law
    sum_i t_i == sum_i d_i (both start at zero), i.e. the tracker's mean
    always equals the mean of the latest pseudo-gradients (pinned in
    tests/test_property.py).  Round 0 reduces to plain SGD.
    """

    tracks = True

    def __init__(self, weight_decay: float = 0.0, K: int = 1,
                 use_kernel: bool = False):
        super().__init__(weight_decay=weight_decay, use_kernel=use_kernel)
        self.K = K

    def init_state(self, cfg, stacked_params):
        return {"d_prev": jax.tree.map(jnp.zeros_like, stacked_params)}

    def init_track(self, cfg, stacked_params):
        return jax.tree.map(jnp.zeros_like, stacked_params)

    def step(self, params, grads, state, anchor, lr):
        g = self._decayed(grads, params)
        corrected = jax.tree.map(
            lambda gi, d, t: gi + (t.astype(gi.dtype) - d.astype(gi.dtype)),
            g, state["d_prev"], state["track"])
        return self._apply(params, corrected, lr), state

    def finalize(self, params_K, state, anchor, lr):
        inv = 1.0 / (jnp.float32(self.K) * lr)
        d_new = jax.tree.map(
            lambda a, y: ((a.astype(jnp.float32) - y.astype(jnp.float32))
                          * inv).astype(a.dtype),
            anchor, params_K)
        msg = jax.tree.map(lambda t, dn, dp: (t + dn - dp).astype(t.dtype),
                           state["track"], d_new, state["d_prev"])
        return {"d_prev": d_new, "track": msg}, params_K

    def state_specs(self, param_specs, client_axis):
        return {"d_prev": param_specs}


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SolverEntry:
    """Registry row: the solver factory and the simulators ("dfl" /
    "cfl") allowed to run it."""

    factory: Callable[[Any], LocalSolver]
    scopes: tuple[str, ...]


SOLVERS: dict[str, SolverEntry] = {}


def register_solver(name: str, factory: Callable[[Any], LocalSolver],
                    scopes: tuple[str, ...] = ("dfl",),
                    overwrite: bool = False) -> None:
    """Register ``factory(cfg) -> LocalSolver`` under ``name``.

    ``scopes`` lists the simulators allowed to run it: ``"dfl"`` (the
    decentralized gossip round) and/or ``"cfl"`` (the centralized server
    round).  Registration is all it takes — the config validators, the
    round builders, and the train CLI all resolve through this table.
    """
    if name in SOLVERS and not overwrite:
        raise ValueError(f"solver {name!r} already registered "
                         "(pass overwrite=True to replace)")
    SOLVERS[name] = SolverEntry(factory=factory, scopes=tuple(scopes))


def solver_names(scope: str | None = None) -> tuple[str, ...]:
    """Registered algorithm names, optionally filtered by scope."""
    return tuple(n for n, e in SOLVERS.items()
                 if scope is None or scope in e.scopes)


def make_solver(cfg) -> LocalSolver:
    """Build the solver named by ``cfg.algorithm``."""
    name = cfg.algorithm
    if name not in SOLVERS:
        raise ValueError(f"unknown algorithm {name!r}; registered solvers: "
                         f"{solver_names()}")
    solver = SOLVERS[name].factory(cfg)
    solver.name = name
    return solver


def _uk(cfg) -> bool:
    uk = getattr(cfg, "use_kernel", False)
    return uk is True or uk == "solver"


# The paper's six DFL algorithms ...
register_solver("dfedadmm",
                lambda cfg: ADMMSolver(lam=cfg.lam, use_kernel=_uk(cfg)))
register_solver("dfedadmm_sam",
                lambda cfg: ADMMSolver(lam=cfg.lam, rho=cfg.rho,
                                       use_kernel=_uk(cfg)))
register_solver("dpsgd",
                lambda cfg: SGDSolver(weight_decay=cfg.weight_decay,
                                      one_step=True, use_kernel=_uk(cfg)))
register_solver("dfedavg",
                lambda cfg: SGDSolver(weight_decay=cfg.weight_decay,
                                      use_kernel=_uk(cfg)))
register_solver("dfedavgm",
                lambda cfg: MomentumSGDSolver(momentum=cfg.momentum,
                                              weight_decay=cfg.weight_decay,
                                              use_kernel=_uk(cfg)))
register_solver("dfedsam",
                lambda cfg: SGDSolver(weight_decay=cfg.weight_decay,
                                      rho=cfg.rho, use_kernel=_uk(cfg)))
# ... the variance-reduction family (control variates / gradient
# tracking / adaptive penalty) ...
register_solver("scaffold",
                lambda cfg: ScaffoldSolver(weight_decay=cfg.weight_decay,
                                           K=cfg.K, use_kernel=_uk(cfg)))
register_solver("dfedtrack",
                lambda cfg: TrackingSolver(weight_decay=cfg.weight_decay,
                                           K=cfg.K, use_kernel=_uk(cfg)))
register_solver("dfedadmm_adaptive",
                lambda cfg: AdaptiveADMMSolver(
                    lam=cfg.lam, use_kernel=_uk(cfg),
                    mu=getattr(cfg, "adapt_mu", None),
                    tau=getattr(cfg, "adapt_tau", None),
                    bound=getattr(cfg, "adapt_bound", None)))
# ... and the centralized baselines the paper compares against.
register_solver("fedavg",
                lambda cfg: SGDSolver(weight_decay=cfg.weight_decay),
                scopes=("cfl",))
register_solver("fedsam",
                lambda cfg: SGDSolver(weight_decay=cfg.weight_decay,
                                      rho=cfg.rho),
                scopes=("cfl",))
register_solver("fedpd",
                lambda cfg: ADMMSolver(lam=cfg.lam, message_dual="new"),
                scopes=("cfl",))
