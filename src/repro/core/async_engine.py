"""Event-driven asynchronous execution engine with bounded-staleness mixing.

The paper's round (Alg. 1) is bulk-synchronous: every client runs K
local iterations, then everyone exchanges at once, and the network cost
model (``repro.core.network``) only *prices* that lockstep after the
fact.  This module promotes the cost model from telemetry to scheduler
(the ROADMAP's event-driven item): each client's next gossip completes
at::

    t_next = t_now + K * compute_s + slowest in-neighbour transfer

from ``NetworkModel.transfer_times`` over the client's round graph, so
fast clients gossip often and a slow-linked client no longer stalls the
federation — the communication/computing balancing of arXiv:2107.12048
without the deadline mode's hard drops.

Tick batching keeps the core jit-friendly.  Virtual time is quantized
into fixed ``tick_s`` windows; the clients whose completion falls inside
the window form the tick's ``active`` set, and one tick is ONE jitted
computation over all m clients with per-client ``(active, steps)``
arrays — exactly the masked-plan machinery ``ParticipationSpec`` already
threads through ``dfl.make_local_phase``, so every registered
``LocalSolver`` / ``Transport`` / ``MessageCodec`` composes unchanged.

Mixing uses bounded-staleness publication buffers.  Each client that
completes a round *publishes* its (codec-decoded) message into its slot
of ``zbuf``; a receiver mixes against the most recent neighbour
publication that has arrived, provided it is at most ``max_staleness``
ticks old.  Stale entries are masked out of the tick's effective mixing
matrix with the lost mass returned to the receiver's self-weight
(:func:`effective_matrix`), so every row still sums to 1 and Definition
1 holds on the tick's effective subgraph.  The push-sum transport is the
exception: its mass-conservation algebra requires a sender's weight to
move when its mass does, so push-sum ticks mix only among
simultaneously-ticking clients (the same column-masking the synchronous
masked round uses) and never consume stale buffers.

Reduction to the synchronous round: with a uniform zero-latency network
and ``tick_s`` at least the round time, every client completes in every
tick, every buffer is fresh, and the tick IS the synchronous round —
``tests/test_async.py`` pins ``history["loss"]`` bitwise for every
registered DFL solver.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import comm as comm_lib, solvers as solvers_lib
from repro.core.comm import _gate_tree
from repro.core.dfl import (DFLConfig, DFLState, consensus_distance,
                            init_state, make_local_phase, mean_params)
from repro.core.gossip import GossipSpec, time_varying_specs
from repro.core.network import NetworkModel
from repro.core.participation import round_participation
from repro.core.sam import global_norm

PyTree = Any


def effective_matrix(w: np.ndarray, receiving: np.ndarray,
                     fresh: np.ndarray, *, column: bool = False
                     ) -> np.ndarray:
    """This tick's effective mixing matrix under asymmetric masks.

    ``receiving[i]`` — client i completes a round this tick and mixes;
    ``fresh[j]``     — client j's buffered publication is young enough
    (age <= max_staleness) to be consumed.  Off-diagonal entry (i, j)
    survives iff ``receiving[i] & fresh[j]``; the removed mass returns
    to the diagonal, so rows still sum to 1 (``column=True``: the
    column-stochastic analogue for push-sum plans — columns sum to 1).
    Non-receiving clients get identity rows and hold their state.

    With ``receiving == fresh`` this is exactly
    ``gossip.mask_and_renormalize`` (same operation order, so the f32
    plan is bit-identical at full masks — the sync-reduction pin rests
    on it).
    """
    w = np.asarray(w, dtype=np.float64)
    m = w.shape[0]
    receiving = np.asarray(receiving, dtype=bool)
    fresh = np.asarray(fresh, dtype=bool)
    if receiving.shape != (m,) or fresh.shape != (m,):
        raise ValueError(
            f"mask shapes {receiving.shape}/{fresh.shape} do not match "
            f"m={m}")
    wm = np.where(np.outer(receiving, fresh), w, 0.0)
    np.fill_diagonal(wm, 0.0)
    np.fill_diagonal(wm, 1.0 - wm.sum(axis=0 if column else 1))
    return wm


@dataclasses.dataclass(frozen=True)
class TickEvents:
    """Host-side realization of one tick from :class:`AsyncScheduler`."""

    tick: int
    active: np.ndarray     # (m,) bool — completes a round inside this tick
    steps: np.ndarray      # (m,) int32 — local iterations (0 if not active)
    fresh: np.ndarray      # (m,) bool — buffer young enough to be mixed
    ages: np.ndarray       # (m,) int — buffer age in ticks (0 for active)
    lr_rounds: np.ndarray  # (m,) int — rounds completed BEFORE this tick
                           # (drives each client's own lr decay)
    sim_dt: float          # virtual seconds this tick advanced the clock
    staleness: int         # max age among buffers some receiver consumes


class AsyncScheduler:
    """Host-side event queue quantized into ``tick_s`` windows.

    Tiny per-client numpy state, never enters jit (like the gossip
    matrices and participation masks):

    * ``done``        — each client's in-flight round completion time;
    * ``clock``       — per-client virtual clock: the completion time of
      the client's last *applied* round (non-decreasing);
    * ``last_pub``    — tick index of each client's last publication;
    * ``rounds_done`` — per-client completed-round counters.

    A client whose ``done`` falls inside the tick window completes its
    round, publishes, and immediately starts the next one:
    ``done += K * compute_s + transfer_times(...)[i]`` over its next
    round's graph (one round per tick at most — ``tick_s`` far above the
    round time degenerates to the synchronous schedule, which is the
    bit-identity pin).  Sampling participation composes: a sampled-out
    client simply defers its completion to the next tick it is sampled.
    """

    def __init__(self, cfg: DFLConfig, net: NetworkModel,
                 specs: list[GossipSpec], bytes_per_client: int):
        m = cfg.m
        self.cfg = cfg
        self.net = net
        self.specs = specs
        self.nbytes = bytes_per_client
        self.part = None if cfg.participation.is_trivial else \
            cfg.participation
        self._transfer_cache: dict[int, np.ndarray] = {}
        self.done = cfg.K * net.compute_s + self._transfer(0)
        self.clock = np.zeros(m, dtype=np.float64)
        self.last_pub = np.zeros(m, dtype=np.int64)
        self.rounds_done = np.zeros(m, dtype=np.int64)
        self._applied_max = 0.0

    def _transfer(self, r: int) -> np.ndarray:
        """(m,) per-client slowest in-neighbour transfer for round ``r``
        (jitter drawn at round index ``r``, graph from ``specs[r]``)."""
        if r not in self._transfer_cache:
            s = self.specs[min(r, len(self.specs) - 1)]
            self._transfer_cache[r] = self.net.transfer_times(
                s.matrix, self.nbytes, r)
        return self._transfer_cache[r]

    def step(self, t: int) -> TickEvents:
        """Advance to tick ``t`` (windows are [t*tick_s, (t+1)*tick_s])."""
        cfg = self.cfg
        m = cfg.m
        lr_rounds = self.rounds_done.copy()
        ticking = self.done <= (t + 1) * cfg.tick_s
        if self.part is not None:
            rp = round_participation(self.part, m, t, cfg.K)
            active = ticking & rp.active
            steps = np.where(active, rp.steps, 0).astype(np.int32)
        else:
            active = ticking.copy()
            steps = np.where(active, cfg.K, 0).astype(np.int32)
        ages = np.where(active, 0, t - self.last_pub).astype(np.int64)
        fresh = ages <= cfg.max_staleness
        # staleness telemetry: the max age among buffered senders some
        # active receiver actually hears through this tick's graph
        w = np.asarray(self.specs[min(t, len(self.specs) - 1)].matrix)
        edges = w != 0.0
        np.fill_diagonal(edges, False)
        heard = edges[active].any(axis=0) if active.any() else \
            np.zeros(m, dtype=bool)
        used = fresh & heard
        staleness = int(ages[used].max()) if used.any() else 0
        # apply the completions: virtual clocks jump to the applied
        # completion times; sim_dt is how far the latest applied event
        # moved the federation's clock (cumsum = the virtual time the
        # post-tick state exists at)
        prev = self._applied_max
        self.clock = np.where(active, self.done, self.clock)
        if active.any():
            self._applied_max = max(self._applied_max,
                                    float(self.clock.max()))
        sim_dt = self._applied_max - prev
        self.last_pub = np.where(active, t, self.last_pub)
        self.rounds_done = self.rounds_done + active.astype(np.int64)
        for i in np.flatnonzero(active):
            r = int(self.rounds_done[i])
            self.done[i] += cfg.K * self.net.compute_s + \
                self._transfer(r)[i]
        return TickEvents(tick=t, active=active, steps=steps, fresh=fresh,
                          ages=ages, lr_rounds=lr_rounds, sim_dt=sim_dt,
                          staleness=staleness)


def make_tick_round(loss_fn: Callable[[PyTree, Any, jax.Array], jax.Array],
                    cfg: DFLConfig, spec: GossipSpec | None = None,
                    metrics: str = "full"):
    """Build ``tick_fn(state, zbuf, tbuf, batches, plan, active, steps,
    lr_rounds) -> (state, zbuf, tbuf, metrics)`` — one async tick as ONE
    jitted computation.

    ``zbuf`` is the (m, ...)-per-leaf publication buffer: slot i holds
    client i's most recent published (codec-decoded) message.  ``tbuf``
    is the analogous publication buffer for a tracking solver's variate
    messages (None for the non-tracking zoo): a ticking client publishes
    its outgoing track message into its slot, the buffer mixes under the
    SAME plan as ``zbuf``, and only the ticking clients consume the
    mixed variate into ``state.comm["track"]`` — at full ticks this
    degenerates to the sync round's track contraction bit for bit.  The
    tick runs the shared masked local phase (``dfl.make_local_phase``)
    with a per-client lr vector (each client decays by its OWN completed
    round count, ``lr_rounds``), publishes the active clients' messages
    into ``zbuf``, mixes the buffer under ``plan`` (from
    :func:`effective_matrix` / ``Transport.prepare``), and keeps the
    mixed result only for the active clients — everyone else's params,
    solver state, codec residual, and push-sum weight pass through
    untouched via the same ``jnp.where`` gating the masked sync round
    uses.
    """
    transport = comm_lib.make_transport(cfg, spec=spec)
    codec = comm_lib.make_codec(cfg)
    solver = solvers_lib.make_solver(cfg)
    local_phase = make_local_phase(loss_fn, cfg, solver, masked=True,
                                   per_client_lr=True)
    # adversarial layer: same seeded persistent adversary set as the sync
    # round (repro.core.threat); an adversary attacks only on the ticks
    # it publishes
    attack, adv_mask = None, None
    if cfg.threat is not None and not cfg.threat.is_trivial:
        from repro.core import threat as threat_lib
        adv_np = threat_lib.adversary_mask(cfg.threat, cfg.m)
        if adv_np.any():
            attack = threat_lib.make_attack(cfg.threat)
            adv_mask = jnp.asarray(adv_np)

    def tick_fn(state: DFLState, zbuf: PyTree, tbuf: PyTree, batches: PyTree,
                plan, active: jax.Array, steps: jax.Array,
                lr_rounds: jax.Array):
        lr_t = cfg.lr * (cfg.lr_decay ** lr_rounds.astype(jnp.float32))
        rngs = jax.vmap(
            lambda k: jax.random.fold_in(k, state.round))(state.rng)
        sstate = state.solver
        if solver.tracks:
            sstate = dict(state.solver, track=state.comm["track"])
        params_K, new_solver, z, losses = local_phase(
            state.params, sstate, batches, rngs, lr_t,
            active, steps)
        track_msg = None
        if solver.tracks:
            new_solver = dict(new_solver)
            track_msg = new_solver.pop("track")

        if adv_mask is not None:
            # perturb the outgoing message of the adversaries that
            # publish this tick (a non-ticking adversary sends nothing,
            # and its z is the anchor the gating must preserve)
            atk_rng = jax.random.fold_in(
                jax.random.fold_in(state.rng[0], state.round), 0xBAD)
            z = attack.perturb(z, jnp.logical_and(adv_mask, active),
                               atk_rng)

        wire_metrics = {}
        aux = state.comm if state.comm is not None else {}
        if codec.stateful:
            codec_rng = jax.random.fold_in(
                jax.random.fold_in(state.rng[0], state.round), 0x51AB3)
            wire, new_resid = codec.encode(z, aux.get("residual"),
                                           codec_rng, active)
            wire_metrics = codec.wire_metrics(wire)
            zhat = codec.decode(wire)
        else:
            zhat, new_resid = z, None
        # publish: active clients overwrite their buffer slot with this
        # round's (decoded) message; every other slot keeps its last
        # publication — the bounded-staleness state the plan masks by age
        new_zbuf = _gate_tree(active, zhat, zbuf)
        mixed, new_ps = transport.mix(new_zbuf, plan,
                                      aux.get("ps_weight"))
        # only the clients that completed a round this tick consume the
        # mix; a busy client's buffer slot is NOT its current params, so
        # (unlike the sync masked round) identity plan rows alone cannot
        # hold it in place — gate explicitly
        new_params = _gate_tree(active, mixed, params_K)

        new_tbuf = tbuf
        new_comm = state.comm
        if state.comm is not None:
            new_comm = dict(state.comm)
            if "ps_weight" in new_comm:
                new_comm["ps_weight"] = new_ps
            if "residual" in new_comm:
                new_comm["residual"] = new_resid
            if track_msg is not None:
                # publish the ticking clients' variate messages, mix the
                # buffer under the same plan as zbuf, and let only the
                # ticking clients consume the mixed variate (a busy
                # client's buffered slot is its LAST publication, so the
                # explicit gate mirrors the params handling above); at
                # full ticks this is the sync track contraction bitwise
                new_tbuf = _gate_tree(active, track_msg, tbuf)
                mixed_t, _ = transport.mix(new_tbuf, plan,
                                           aux.get("ps_weight"))
                new_comm["track"] = _gate_tree(active, mixed_t,
                                               state.comm["track"])

        af = active.astype(jnp.float32)
        # mean over this tick's active clients, written exactly like the
        # masked sync round so the full-tick loss matches the sync round
        # bit for bit (see make_train_round)
        n_active = jnp.sum(af)
        mean_loss = jnp.mean(losses * af) * (
            jnp.float32(cfg.m) / jnp.maximum(n_active, 1.0))
        out_metrics = {
            "loss": jnp.where(n_active > 0, mean_loss, jnp.nan),
            "lr": jnp.max(jnp.where(active, lr_t, 0.0)),
            "ticked": jnp.mean(af),
        }
        out_metrics.update(wire_metrics)
        if metrics == "full":
            out_metrics["consensus_sq"] = consensus_distance(new_params)
            d = solver.dual_tree(new_solver)
            out_metrics["dual_norm"] = global_norm(d) if d is not None \
                else jnp.zeros((), jnp.float32)
        new_state = DFLState(params=new_params, solver=new_solver,
                             rng=state.rng, round=state.round + 1,
                             comm=new_comm)
        return new_state, new_zbuf, new_tbuf, out_metrics

    return tick_fn


def _tick_plan(transport: comm_lib.Transport, spec: GossipSpec,
               active: np.ndarray, fresh: np.ndarray):
    """This tick's communication plan.  Row-stochastic transports mix
    active receivers against fresh buffers (:func:`effective_matrix`);
    push-sum keeps its mass-conservation invariant by exchanging only
    among simultaneously-ticking clients (``Transport.prepare`` applies
    the column masking), never stale buffers."""
    if transport.kind == "pushsum":
        return transport.prepare(spec,
                                 None if active.all() else active)
    if transport.kind == "hier":
        # two-tier plan: the staleness gating applies per tier.  A
        # non-receiving client is an identity row in BOTH tiers, so the
        # sequential product holds its state exactly; stale neighbours
        # are renormalized away at each tier independently.
        return {"intra": jnp.asarray(
                    effective_matrix(transport.w_intra, active, fresh),
                    jnp.float32),
                "inter": jnp.asarray(
                    effective_matrix(transport.w_inter, active, fresh),
                    jnp.float32)}
    w = effective_matrix(spec.matrix, active, fresh)
    return jnp.asarray(w, jnp.float32)


def simulate_async(loss_fn, eval_fn, params_single: PyTree, cfg: DFLConfig,
                   sample_batches: Callable[[int], PyTree], ticks: int,
                   seed: int = 0, eval_every: int = 10,
                   verbose: bool = False):
    """Run ``ticks`` async ticks; returns (state, history) with the same
    contract as ``dfl.simulate`` (which dispatches here when
    ``cfg.execution == "async"``).

    History rows are per TICK: ``sim_time`` is the virtual seconds each
    tick advanced the applied-event clock (cumsum = time-to-that-state,
    the quantity ``benchmarks.common.time_from_history`` integrates),
    ``staleness`` the max buffer age some receiver consumed,
    ``ticked`` the fraction of clients that completed a round, and
    ``wire_bytes`` the tick's published bytes (active clients x codec
    message size).  A tick in which no client completes touches nothing:
    no jitted call runs and the row records loss NaN / sim_time 0.
    """
    if cfg.execution != "async":
        raise ValueError(
            f"simulate_async needs cfg.execution='async', "
            f"got {cfg.execution!r}")
    if cfg.transport == "ppermute" and cfg.topology in ("random", "drandom"):
        raise ValueError(
            f"topology={cfg.topology!r} draws a fresh non-circulant graph "
            "every round, but the ppermute transport compiles one static "
            "neighbour pattern and would silently gossip over round 0's "
            "graph forever; use transport='dense' for time-varying "
            "topologies")
    specs = time_varying_specs(cfg.topology, cfg.m, ticks,
                               degree=cfg.degree, base_seed=seed,
                               weights=cfg.weights)
    spec0 = specs[0]
    net = cfg.make_network_model(seed=seed)
    transport = comm_lib.make_transport(cfg, spec=spec0)
    codec = comm_lib.make_codec(cfg)
    bytes_per_client = codec.bytes_per_client(params_single)
    if solvers_lib.make_solver(cfg).tracks:
        # the tracking solver's second (uncompressed) gossip message —
        # priced identically to the sync path so the sim_time pin holds
        bytes_per_client += comm_lib.IdentityCodec().bytes_per_client(
            params_single)
    scheduler = AsyncScheduler(cfg, net, specs, bytes_per_client)
    tick_fn = jax.jit(make_tick_round(loss_fn, cfg, spec=spec0))
    state = init_state(params_single, cfg, seed=seed)
    # common init (paper: x^0 everywhere) doubles as everyone's first
    # publication, so round-0 receivers mix against the true x^0
    zbuf = state.params
    # ... and the zero-initialized tracking buffer doubles as everyone's
    # first variate publication (None for the non-tracking zoo)
    tbuf = None if state.comm is None else state.comm.get("track")

    history: dict[str, list] = {"round": [], "loss": [], "lr": [],
                                "consensus_sq": [], "dual_norm": [],
                                "wire_bytes": [], "wall_us": [],
                                "sim_time": [], "staleness": [],
                                "ticked": []}
    for k in codec.metric_names():
        history[k] = []                 # e.g. dp codec clip-fraction rows
    eval_hist: dict[str, list] = {}
    for t in range(ticks):
        ev = scheduler.step(t)
        n_active = int(ev.active.sum())
        if n_active > 0:
            plan = _tick_plan(transport, specs[t], ev.active, ev.fresh)
            batches = sample_batches(t)
            t0 = time.perf_counter()
            state, zbuf, tbuf, metrics = tick_fn(
                state, zbuf, tbuf, batches, plan, jnp.asarray(ev.active),
                jnp.asarray(ev.steps),
                jnp.asarray(ev.lr_rounds, jnp.int32))
            jax.block_until_ready((state.params, metrics))
            history["wall_us"].append((time.perf_counter() - t0) * 1e6)
            for k in ("loss", "lr", "consensus_sq", "dual_norm", "ticked") \
                    + codec.metric_names():
                history[k].append(float(metrics[k]))
        else:
            # empty window: no completions, no jitted call, state frozen
            history["wall_us"].append(0.0)
            for k in ("loss", "lr", "consensus_sq", "dual_norm") \
                    + codec.metric_names():
                history[k].append(float("nan"))
            history["ticked"].append(0.0)
        history["round"].append(t)
        # uplink accounting: ONLY the clients that ticked published a
        # message this window — bytes = codec size x ticking clients,
        # never x m (regression-pinned in tests/test_async.py)
        history["wire_bytes"].append(bytes_per_client * n_active)
        history["sim_time"].append(ev.sim_dt)
        history["staleness"].append(ev.staleness)
        if eval_fn is not None and ((t + 1) % eval_every == 0
                                    or t == ticks - 1):
            evm = eval_fn(mean_params(state.params))
            eval_hist.setdefault("round", []).append(t)
            for k, v in evm.items():
                eval_hist.setdefault(k, []).append(float(v))
            if verbose:
                print(f"tick {t+1:4d} loss={history['loss'][-1]:.4f} "
                      f"ticked={history['ticked'][-1]:.2f} "
                      + " ".join(f"{k}={v[-1]:.4f}"
                                 for k, v in eval_hist.items()
                                 if k != "round"))
    history["eval"] = eval_hist
    return state, history


class VirtualScheduler:
    """Per-cohort ticks over a VIRTUAL population (``repro.core.cohort``).

    The event queue of :class:`AsyncScheduler` scaled past the device:
    tiny numpy arrays over all ``n_virtual`` clients, never entering
    jit.  Each virtual client inherits the network personality of its
    cohort *slot* (``id % m`` — the (m, m) cost model tiles across the
    population) and re-enters gossip when its modeled compute + worst
    in-link period elapses.  A tick gathers the ready clients —
    earliest-done first, at most one cohort's worth; the rest stay
    queued — into hot slots and runs one masked synchronous round over
    them, so staleness never exceeds a tick window (the cohort *is* the
    publication set) and the jitted computation keeps the static cohort
    shape.
    """

    def __init__(self, cfg: DFLConfig, net: NetworkModel, n_virtual: int,
                 bytes_per_client: int):
        m = cfg.m
        lt = net.link_seconds(bytes_per_client, 0)
        off_diag = ~np.eye(m, dtype=bool)
        slot_in = np.where(off_diag, lt, 0.0).max(axis=1)
        period = cfg.K * net.compute_s + slot_in
        self.period = period[np.arange(n_virtual) % m]
        self.done = self.period.copy()
        self.tick_s = cfg.tick_s
        self.cohort = m

    def step(self, t: int) -> np.ndarray:
        """Virtual-client ids completing inside tick ``t``'s window,
        earliest first, capped at the cohort size (the overflow keeps
        its completion time and boards a later tick)."""
        horizon = (t + 1) * self.tick_s
        ready = np.flatnonzero(self.done <= horizon)
        ready = ready[np.argsort(self.done[ready], kind="stable")]
        return ready[:self.cohort]

    def advance(self, ids: np.ndarray) -> None:
        """The ticked clients start their next round immediately."""
        self.done[ids] += self.period[ids]
