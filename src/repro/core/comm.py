"""Pluggable communication layer: transports and message codecs.

Alg. 1 line 19 is one symmetric gossip step; this module makes the whole
wire level a first-class API so scenario work (directed links, bandwidth
limits, sparsification) composes with the round loop instead of forking
it.  Two protocols:

``Transport`` — how messages move between clients::

    plan        = transport.prepare(spec, active)   # host-side, per round
    x, aux      = transport.mix(z, plan, aux)       # inside the jitted round

``prepare`` turns this round's ``GossipSpec`` + optional participation
mask into a *plan* — a pytree of arrays passed through jit (a masked
matrix, ppermute gate vectors, ...) — so partial participation composes
uniformly with every transport; it subsumes the old direct
``gossip.mask_and_renormalize`` call sites.  ``aux`` is the transport's
persistent per-client state (``DFLState.comm``), e.g. the push-sum
weights.  Four implementations:

* ``DenseTransport``     — einsum against the (masked) matrix; wraps the
  seed ``mixing.mix_dense`` path bit-identically.
* ``PpermuteTransport``  — neighbour collective_permute on a mesh
  (circulant topologies).  With a participation mask the permute sends
  are *gated* per client (``mixing.mix_ppermute_masked``), realizing the
  masked matrix on the sharded substrate without materializing it.
* ``PushSumTransport``   — asymmetric/directed gossip.  Accepts any row-
  or column-stochastic matrix (symmetric doubly-stochastic ones work
  unchanged) and threads a per-client push-sum weight through ``aux`` so
  one-directional links still converge to the true average: biased
  messages ``pi_j * z_j`` are mixed with the column-stochastic matrix,
  weights follow the same contraction, and the de-biased parameters are
  the elementwise ratio.  With a doubly stochastic matrix the weights
  stay exactly uniform and the step reduces to plain dense mixing.  On
  a sharded mesh with a directed circulant topology the same algebra
  runs on the ppermute substrate (``mixing.mix_pushsum_ppermute``).
* ``HierTransport``      — two-tier hierarchical gossip: a dense
  metropolis step inside each contiguous cluster, then a ring step over
  the cluster heads.  Both tiers are Definition-1 matrices, both are
  masked per round, and ``sim_tiers`` exposes them so the network model
  prices the tiers as sequential critical paths.

``MessageCodec`` — what goes on the wire::

    wire, resid = codec.encode(z, resid, rng, active)
    zhat        = codec.decode(wire)

* ``identity`` — passthrough (returns ``z`` itself: bit-exact, zero cost).
* ``int8``     — per-client symmetric-scale stochastic-rounding
  quantization to ``codec_bits`` <= 8 bits (int8 container), fused
  quantize+residual Pallas kernel (``kernels/quantize.py``) behind
  ``use_kernel``.
* ``fp8``      — e4m3 float wire with per-client scale: same 4x
  compression as int8 but relative mantissa spacing, so no stochastic
  rounding is needed (EF absorbs the deterministic RNE bias); values
  are clipped to +-448 before the cast because XLA's float8 conversion
  overflows to NaN instead of saturating.
* ``topk``     — per-client magnitude top-``codec_k`` sparsification.

The lossy codecs carry per-client error-feedback residuals
(``DFLState.comm["residual"]``): each round encodes ``z + resid`` and
carries the quantization error forward, so the *sum* of decoded messages
telescopes to the sum of true messages and compressed runs still
converge.  ``bytes_per_client`` reports the modeled wire size for the
bandwidth telemetry (``history["wire_bytes"]``, ``comm_bench``).
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mixing
from repro.core._registry import FactoryRegistry
from repro.core.gossip import (GossipSpec, as_column_stochastic,
                               mask_and_renormalize,
                               mask_and_renormalize_columns)

PyTree = Any

TRANSPORTS = ("dense", "ppermute", "pushsum", "hier")
CODECS = ("identity", "int8", "fp8", "topk", "randk", "dp")


# ---------------------------------------------------------------------------
# Transports
# ---------------------------------------------------------------------------

class Transport:
    """Protocol: ``prepare(spec, active) -> plan``;
    ``mix(z, plan, aux) -> (x, aux)``; ``init_aux(m) -> aux | None``."""

    kind: str = ""

    def prepare(self, spec: GossipSpec, active: np.ndarray | None = None):
        """Host-side, once per round: fold this round's gossip ``spec``
        and optional (m,) ``active`` mask into a *plan* — a pytree of
        arrays the jitted round consumes as data."""
        raise NotImplementedError

    def mix(self, z: PyTree, plan, aux=None):
        """Inside jit: mix the (m, ...)-stacked messages ``z`` under
        ``plan``; ``aux`` is this transport's persistent per-client
        state (``DFLState.comm`` slot).  Returns ``(x, aux')``."""
        raise NotImplementedError

    def init_aux(self, m: int):
        """Initial persistent per-client state for ``m`` clients (None
        for stateless transports)."""
        return None

    def sim_tiers(self, spec: GossipSpec,
                  active: np.ndarray | None = None) -> list | None:
        """Edge matrices for the network cost model, one per sequential
        communication tier, or None for single-tier transports (the
        round is then priced off the spec matrix directly — the seed
        path, bit-unchanged).  The hierarchical transport returns its
        masked intra/inter tier matrices so ``simulate`` can price the
        tiers as sequential critical paths
        (``NetworkModel.tiered_round_time``)."""
        return None


class DenseTransport(Transport):
    """Any-topology einsum mixing — the seed path, verbatim."""

    kind = "dense"

    def prepare(self, spec: GossipSpec, active: np.ndarray | None = None):
        w = spec.matrix
        if active is not None:
            w = mask_and_renormalize(w, active)
        return jnp.asarray(w, jnp.float32)

    def mix(self, z, plan, aux=None):
        return mixing.mix_dense(plan, z), aux


class PpermuteTransport(Transport):
    """Neighbour-only collective_permute mixing for circulant topologies.

    The offset->weight pattern is static (baked into the compiled round
    from the ``spec`` given at construction); participation enters as
    per-round gate arrays in the plan, so the same fixed communication
    schedule serves every mask.  Without a mesh (single-device
    simulation) the transport falls back to the equivalent dense einsum.
    """

    kind = "ppermute"

    def __init__(self, spec: GossipSpec, mesh=None, client_axis: str = "data",
                 inner_specs: PyTree | None = None):
        if spec is None:
            raise ValueError("ppermute transport needs a static GossipSpec")
        mixing._circulant_pattern(spec)      # raises for non-circulant
        self.spec = spec
        self.mesh = mesh
        self.client_axis = client_axis
        self.inner_specs = inner_specs

    def prepare(self, spec: GossipSpec, active: np.ndarray | None = None):
        if spec is not None and spec is not self.spec and \
                not np.array_equal(spec.matrix, self.spec.matrix):
            # the offset->weight pattern is baked into the compiled round:
            # a different per-round matrix (e.g. a time-varying topology)
            # would silently gossip over the construction-time graph
            raise ValueError(
                f"ppermute pattern was compiled for {self.spec.topology!r} "
                f"and cannot realize this round's {spec.topology!r} matrix; "
                "use the dense transport for time-varying topologies")
        if active is None:
            return None                       # static unmasked pattern
        if self.mesh is None:
            # dense fallback executes the masked matrix directly
            return jnp.asarray(
                mask_and_renormalize(self.spec.matrix, active), jnp.float32)
        gates, self_w = mixing.ppermute_gates(self.spec, active)
        return {"gates": jnp.asarray(gates), "self_w": jnp.asarray(self_w)}

    def mix(self, z, plan, aux=None):
        if isinstance(plan, dict):            # masked, on-mesh
            return mixing.mix_ppermute_masked(
                z, plan["gates"], plan["self_w"], self.spec, self.mesh,
                self.client_axis, inner_specs=self.inner_specs), aux
        if self.mesh is None:
            # plan is the masked matrix, or None / an ignored raw matrix
            # (the legacy round_fn signature passes one) at full
            # participation — identical to the seed fallback either way
            w = plan if plan is not None else self.spec.matrix
            return mixing.mix_dense(w, z), aux
        return mixing.mix_ppermute(z, self.spec, self.mesh, self.client_axis,
                                   inner_specs=self.inner_specs), aux


class PushSumTransport(Transport):
    """Directed gossip with the push-sum weight correction.

    ``aux`` is the per-client weight vector pi (m,) f32, initialized
    uniform at 1/m.  One round::

        u_i   = sum_j P_ij * pi_j * z_j      (biased mix, f32)
        pi'_i = sum_j P_ij * pi_j
        x_i   = u_i / pi'_i                  (de-biased parameters)

    With P column stochastic the weighted sums ``sum_j pi_j z_j`` and
    ``sum_j pi_j`` are conserved exactly, so repeated rounds drive every
    client to the true initial average regardless of how asymmetric the
    link structure is; pi converges to the Perron vector of P (uniform
    1/m for a directed ring).
    """

    kind = "pushsum"

    def __init__(self, spec: GossipSpec | None = None, mesh=None,
                 client_axis: str = "data",
                 inner_specs: PyTree | None = None):
        self._ps_spec = None
        if mesh is not None:
            if spec is None:
                raise ValueError(
                    "on-mesh push-sum needs a static GossipSpec (the "
                    "permute offsets are baked into the compiled round)")
            self._ps_spec = GossipSpec(
                topology=spec.topology,
                matrix=as_column_stochastic(spec.matrix), psi=spec.psi)
            mixing._circulant_pattern(self._ps_spec)  # non-circulant raises
        self.spec = spec
        self.mesh = mesh
        self.client_axis = client_axis
        self.inner_specs = inner_specs

    def prepare(self, spec: GossipSpec, active: np.ndarray | None = None):
        if self.mesh is not None:
            if spec is not None and spec is not self.spec and \
                    not np.array_equal(spec.matrix, self.spec.matrix):
                raise ValueError(
                    "the push-sum permute pattern was compiled for "
                    f"{self.spec.topology!r} and cannot realize this "
                    f"round's {spec.topology!r} matrix; use the meshless "
                    "push-sum path for time-varying topologies")
            if active is not None:
                raise ValueError(
                    "on-mesh push-sum gossips the full static pattern; "
                    "compose partial participation with the meshless "
                    "(dense-plan) push-sum transport")
            return None                       # static permute pattern
        p = as_column_stochastic(spec.matrix)
        if active is not None:
            p = mask_and_renormalize_columns(p, active)
        return jnp.asarray(p, jnp.float32)

    def mix(self, z, plan, aux=None):
        if aux is None:
            raise ValueError(
                "push-sum needs its weight state: initialize DFLState.comm "
                "via init_state (or Transport.init_aux)")
        if self.mesh is not None and plan is None:
            # directed circulant on the sharded substrate: biased
            # messages ride the neighbour permutes, the ps_weight scalar
            # rides one extra permute chain (mixing.mix_pushsum_ppermute)
            return mixing.mix_pushsum_ppermute(
                z, aux.astype(jnp.float32), self._ps_spec,
                self.mesh, self.client_axis, inner_specs=self.inner_specs)
        pi = aux.astype(jnp.float32)
        weighted = plan * pi[None, :]
        pi_new = plan @ pi
        m = pi.shape[0]

        def leaf(arr):
            u = jnp.einsum("ij,j...->i...", weighted,
                           arr.astype(jnp.float32))
            return (u / pi_new.reshape((m,) + (1,) * (arr.ndim - 1))
                    ).astype(arr.dtype)

        return jax.tree.map(leaf, z), pi_new

    def init_aux(self, m: int):
        return jnp.full((m,), 1.0 / m, jnp.float32)


class HierTransport(Transport):
    """Two-tier hierarchical gossip: dense intra-cluster + sparse
    inter-cluster.

    The m cohort slots form ``clusters`` contiguous clusters
    (``gossip.cluster_labels``).  One round runs two sequential
    Definition-1 gossip steps built by ``gossip.hier_tier_matrices``:

    * tier 1 (``intra``) — complete-graph metropolis gossip inside each
      cluster (fast LAN links under the cluster-aware ``hub-and-spoke``
      network preset);
    * tier 2 (``inter``) — ring gossip over the cluster heads, identity
      for everyone else (the sparse backbone).

    ``prepare`` masks each tier with the round's participation mask
    (``mask_and_renormalize`` per tier), so partial participation, wire
    codecs (the decoded estimates feed both tiers), robust wrapping
    (``threat.RobustTransport`` aggregates per tier), and the network
    model (``sim_tiers`` prices the tiers as sequential critical paths)
    all compose per tier.  The per-round ``spec`` matrix is *not* used:
    the hierarchy replaces the flat topology.
    """

    kind = "hier"

    def __init__(self, m: int, clusters: int = 0,
                 weights: str = "metropolis"):
        from repro.core.gossip import hier_tier_matrices, resolve_clusters
        self.m = m
        self.clusters = resolve_clusters(m, clusters)
        self.w_intra, self.w_inter = hier_tier_matrices(
            m, self.clusters, weights=weights)

    def _masked(self, active):
        if active is None:
            return self.w_intra, self.w_inter
        return (mask_and_renormalize(self.w_intra, active),
                mask_and_renormalize(self.w_inter, active))

    def prepare(self, spec: GossipSpec, active: np.ndarray | None = None):
        wi, wo = self._masked(active)
        return {"intra": jnp.asarray(wi, jnp.float32),
                "inter": jnp.asarray(wo, jnp.float32)}

    def mix(self, z, plan, aux=None):
        x = mixing.mix_dense(plan["intra"], z)
        return mixing.mix_dense(plan["inter"], x), aux

    def sim_tiers(self, spec: GossipSpec,
                  active: np.ndarray | None = None) -> list:
        return list(self._masked(active))


def make_transport(cfg, spec: GossipSpec | None = None, mesh=None,
                   client_axis: str = "data",
                   inner_specs: PyTree | None = None) -> Transport:
    """Build the transport named by ``cfg.transport``.

    Args: ``spec`` — static GossipSpec (required by ppermute, which
    bakes the neighbour pattern into the compiled round); ``mesh`` /
    ``client_axis`` / ``inner_specs`` — the sharded-substrate layout
    for the on-mesh ppermute path (None = single-device simulation).
    """
    name = cfg.transport
    if name == "dense":
        base = DenseTransport()
    elif name == "ppermute":
        base = PpermuteTransport(spec, mesh=mesh, client_axis=client_axis,
                                 inner_specs=inner_specs)
    elif name == "pushsum":
        base = PushSumTransport(spec, mesh=mesh, client_axis=client_axis,
                                inner_specs=inner_specs)
    elif name == "hier":
        base = HierTransport(cfg.m, clusters=getattr(cfg, "clusters", 0),
                             weights=getattr(cfg, "weights", "metropolis"))
    else:
        raise ValueError(
            f"unknown transport {name!r}; expected one of {TRANSPORTS}")
    robust = getattr(cfg, "robust", "mean")
    if robust and robust != "mean":
        # the adversarial layer (repro.core.threat): wrap the transport
        # so the mix step applies a per-receiver robust statistic over
        # the plan support instead of the weighted contraction.
        # robust="mean" deliberately returns the UNWRAPPED transport —
        # the zero-adversary code path stays bit-identical to the seed.
        from repro.core import threat as threat_lib
        if name in ("ppermute", "pushsum") and mesh is not None:
            raise ValueError(
                "robust aggregation needs the full neighbourhood "
                "materialized per receiver, which the on-mesh permute "
                "paths never do; use transport='dense' (or the meshless "
                "fallbacks) with robust mixing")
        return threat_lib.RobustTransport(base, threat_lib.make_aggregator(cfg))
    return base


# ---------------------------------------------------------------------------
# Codecs
# ---------------------------------------------------------------------------

class MessageCodec:
    """Protocol: ``encode(z, resid, rng, active) -> (wire, resid)``;
    ``decode(wire) -> zhat``; ``bytes_per_client(params) -> int``."""

    name = "identity"
    stateful = False

    def init_state(self, stacked_params: PyTree):
        """Per-client codec state shaped like ``stacked_params`` (the
        error-feedback residuals for lossy codecs), or None."""
        return None

    def encode(self, z: PyTree, resid=None, rng=None, active=None):
        """Compress the (m, ...)-stacked messages ``z`` for the wire.

        Args: ``resid`` — the per-client residual state (or None),
        ``rng`` — the round's shared codec PRNG key, ``active`` — (m,)
        bool mask (inactive clients transmit nothing, so their residual
        must pass through untouched).  Returns ``(wire, resid')``.
        """
        return z, resid

    def decode(self, wire):
        """Reconstruct the (m, ...)-stacked message estimates from the
        wire representation produced by :meth:`encode`."""
        return wire

    def bytes_per_client(self, params_single: PyTree) -> int:
        """Modeled wire size of one client's message, in bytes — the
        number consumed by ``history["wire_bytes"]`` and the network
        cost model (``repro.core.network``)."""
        return int(sum(leaf.size * leaf.dtype.itemsize
                       for leaf in jax.tree.leaves(params_single)))

    def metric_names(self) -> tuple[str, ...]:
        """Names of the per-round telemetry scalars this codec emits via
        :meth:`wire_metrics` (the round loops allocate one history list
        per name; e.g. the dp codec reports ``dp_clip_frac``)."""
        return ()

    def wire_metrics(self, wire) -> dict:
        """Per-round telemetry scalars computed from this round's
        ``wire`` (traced, inside jit); keys must match
        :meth:`metric_names`."""
        return {}


class IdentityCodec(MessageCodec):
    """Uncompressed wire: ``decode(encode(z)) is z`` — bit-exact."""


def _leaf_rngs(rng, leaves):
    return [jax.random.fold_in(rng, i) for i in range(len(leaves))]


def _gate_tree(active, new, old):
    """Per-client select: keep ``old`` rows where the client is inactive
    (an inactive client transmits nothing, so its codec state and its
    self-message must pass through untouched)."""
    def sel(a, b):
        mask = active.reshape((a.shape[0],) + (1,) * (a.ndim - 1))
        return jnp.where(mask, a, b)
    return jax.tree.map(sel, new, old)


class QuantizeCodec(MessageCodec):
    """Low-bit stochastic-rounding quantization with error feedback.

    Per client and per leaf: symmetric scale ``max|e| / qmax`` over the
    error-compensated message ``e = z + resid``, stochastic rounding to
    ``bits``-bit integers (int8 container), residual ``e - decode(wire)``
    carried to the next round.  ``use_kernel`` dispatches the fused
    Pallas quantize+residual kernel; the default pure-jnp path is the
    ``kernels.ref`` oracle (tested equivalent).
    """

    stateful = True

    def __init__(self, bits: int = 8, use_kernel: bool = False):
        if not 2 <= bits <= 8:
            raise ValueError(f"codec_bits must be in [2, 8], got {bits}")
        self.name = f"int8[{bits}b]" if bits != 8 else "int8"
        self.bits = bits
        self.use_kernel = use_kernel
        self._meta = None                 # [(shape, dtype)] captured at encode

    def init_state(self, stacked_params: PyTree):
        # f32 residuals: the whole point of error feedback is to remember
        # mass smaller than one quantization step
        return jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), stacked_params)

    def encode(self, z, resid=None, rng=None, active=None):
        leaves, treedef = jax.tree.flatten(z)
        self._meta = ([(l.shape, l.dtype) for l in leaves], treedef)
        rleaves = jax.tree.leaves(resid) if resid is not None else \
            [jnp.zeros(l.shape, jnp.float32) for l in leaves]
        qmax = float(2 ** (self.bits - 1) - 1)
        wire_leaves, new_resid = [], []
        for leaf, r, key in zip(leaves, rleaves, _leaf_rngs(rng, leaves)):
            e = leaf.astype(jnp.float32) + r
            u = jax.random.uniform(key, e.shape, jnp.float32)
            if self.use_kernel:
                from repro.kernels import ops
                q, scale, rr = ops.quantize_leaf(e, u, bits=self.bits)
                rr = rr.astype(jnp.float32)
            else:
                m = e.shape[0]
                absmax = jnp.max(jnp.abs(e).reshape(m, -1), axis=1)
                scale = jnp.maximum(absmax, jnp.float32(1e-12)) / qmax
                sb = scale.reshape((m,) + (1,) * (e.ndim - 1))
                q = jnp.clip(jnp.floor(e / sb + u), -qmax, qmax
                             ).astype(jnp.int8)
                rr = e - q.astype(jnp.float32) * sb
            if active is not None:
                # inactive clients transmit nothing: their residual must
                # not absorb a phantom quantization error (the round loop
                # restores their self-message from z directly)
                rr = _gate_tree(active, rr, r)
            wire_leaves.append({"q": q, "scale": scale})
            new_resid.append(rr)
        return (jax.tree.unflatten(treedef, wire_leaves),
                jax.tree.unflatten(treedef, new_resid))

    def decode(self, wire):
        metas, treedef = self._meta
        leaves = treedef.flatten_up_to(wire)
        out = []
        for w, (shape, dtype) in zip(leaves, metas):
            if self.use_kernel:
                from repro.kernels import ops
                out.append(ops.dequantize_leaf(w["q"], w["scale"], shape,
                                               dtype))
            else:
                m = w["q"].shape[0]
                sb = w["scale"].reshape((m,) + (1,) * (len(shape) - 1))
                out.append((w["q"].astype(jnp.float32) * sb).astype(dtype))
        return jax.tree.unflatten(treedef, out)

    def encode_mix_dense(self, z, w, resid=None, rng=None, active=None):
        """Fused wire + mix for the dense transport: one Pallas kernel
        per leaf quantizes the error-compensated message, mixes the
        dequantized estimates with ``w``, and carries the error-feedback
        residual (``kernels/gossip_quant``) — the int8 wire values and
        the f32 message estimates are never materialized in HBM.

        Mathematically identical to ``encode`` -> ``decode`` -> the
        dense ``Transport.mix`` (same PRNG derivation per leaf, so the
        stochastic rounding sees the same uniform bits); dispatched by
        the round loop via :func:`can_fuse_dense`.  Returns
        ``(x, resid')``.
        """
        from repro.kernels import ops
        leaves, treedef = jax.tree.flatten(z)
        rleaves = jax.tree.leaves(resid) if resid is not None else \
            [jnp.zeros(l.shape, jnp.float32) for l in leaves]
        mixed, new_resid = [], []
        for leaf, r, key in zip(leaves, rleaves, _leaf_rngs(rng, leaves)):
            u = jax.random.uniform(key, leaf.shape, jnp.float32)
            y, rr = ops.quantize_mix_leaf(w, leaf, r, u, active,
                                          bits=self.bits)
            mixed.append(y)
            new_resid.append(rr.astype(jnp.float32))
        return (jax.tree.unflatten(treedef, mixed),
                jax.tree.unflatten(treedef, new_resid))

    def bytes_per_client(self, params_single: PyTree) -> int:
        total = 0
        for leaf in jax.tree.leaves(params_single):
            total += math.ceil(self.bits * leaf.size / 8) + 4  # + f32 scale
        return int(total)


class Fp8Codec(MessageCodec):
    """fp8 ``e4m3`` wire with per-client scale and error feedback.

    Hangs off the per-client symmetric-scale plumbing the fused
    quantized-gossip kernels established (``kernels/quantize.py``): per
    client and per leaf the error-compensated message ``e = z + resid``
    is scaled by ``max|e| / 448`` (448 = the e4m3 max normal), cast to
    ``float8_e4m3fn`` with round-to-nearest-even, and the cast error
    rides the shared error-feedback residual.  Values are clipped to
    +-448 *before* the cast: XLA's float8 cast overflows to NaN instead
    of saturating, so an unclipped absmax value would poison the mix.
    Unlike the integer grid, no stochastic rounding is needed — e4m3's
    mantissa spacing is relative, and EF telescopes the deterministic
    bias.  One byte per value + 4 for the f32 scale per leaf.
    """

    name = "fp8"
    stateful = True
    FP8_MAX = 448.0                      # e4m3 max normal magnitude

    def __init__(self):
        self._meta = None

    def init_state(self, stacked_params: PyTree):
        return jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), stacked_params)

    def encode(self, z, resid=None, rng=None, active=None):
        leaves, treedef = jax.tree.flatten(z)
        self._meta = ([(l.shape, l.dtype) for l in leaves], treedef)
        rleaves = jax.tree.leaves(resid) if resid is not None else \
            [jnp.zeros(l.shape, jnp.float32) for l in leaves]
        wire_leaves, new_resid = [], []
        for leaf, r in zip(leaves, rleaves):
            e = leaf.astype(jnp.float32) + r
            m = e.shape[0]
            absmax = jnp.max(jnp.abs(e).reshape(m, -1), axis=1)
            scale = jnp.maximum(absmax, jnp.float32(1e-12)) \
                / jnp.float32(self.FP8_MAX)
            sb = scale.reshape((m,) + (1,) * (e.ndim - 1))
            q = jnp.clip(e / sb, -self.FP8_MAX, self.FP8_MAX
                         ).astype(jnp.float8_e4m3fn)
            rr = e - q.astype(jnp.float32) * sb
            if active is not None:
                rr = _gate_tree(active, rr, r)
            wire_leaves.append({"q": q, "scale": scale})
            new_resid.append(rr)
        return (jax.tree.unflatten(treedef, wire_leaves),
                jax.tree.unflatten(treedef, new_resid))

    def decode(self, wire):
        metas, treedef = self._meta
        leaves = treedef.flatten_up_to(wire)
        out = []
        for w, (shape, dtype) in zip(leaves, metas):
            m = w["q"].shape[0]
            sb = w["scale"].reshape((m,) + (1,) * (len(shape) - 1))
            out.append((w["q"].astype(jnp.float32) * sb).astype(dtype))
        return jax.tree.unflatten(treedef, out)

    def bytes_per_client(self, params_single: PyTree) -> int:
        total = 0
        for leaf in jax.tree.leaves(params_single):
            total += leaf.size + 4               # 1 byte/value + f32 scale
        return int(total)


class _SparseCodec(MessageCodec):
    """Shared scaffolding for index/value sparsifiers: error-feedback
    residuals, per-leaf meta capture, and the scatter decode.

    Subclasses implement ``_select(flat, key) -> (idx, val)`` — ``idx``
    either (m, k) per-client rows or (k,) shared across clients — and
    ``bytes_per_client``.  Everything else (the residual algebra, the
    inactive-client gating, the wire layout) is identical between the
    sparsifiers and lives here exactly once.
    """

    stateful = True

    def __init__(self, k: int = 64):
        if k < 1:
            raise ValueError(f"codec_k must be >= 1, got {k}")
        self.k = k
        self._meta = None

    def init_state(self, stacked_params: PyTree):
        return jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), stacked_params)

    def _select(self, flat, key):
        raise NotImplementedError

    @staticmethod
    def _scatter(flat_zeros, idx, val):
        if idx.ndim == 1:                          # shared columns
            return flat_zeros.at[:, idx].set(val)
        m = flat_zeros.shape[0]                    # per-client rows
        return flat_zeros.at[jnp.arange(m)[:, None], idx].set(val)

    def encode(self, z, resid=None, rng=None, active=None):
        leaves, treedef = jax.tree.flatten(z)
        self._meta = ([(l.shape, l.dtype) for l in leaves], treedef)
        rleaves = jax.tree.leaves(resid) if resid is not None else \
            [jnp.zeros(l.shape, jnp.float32) for l in leaves]
        keys = _leaf_rngs(rng, leaves) if rng is not None else \
            [None] * len(leaves)
        wire_leaves, new_resid = [], []
        for leaf, r, key in zip(leaves, rleaves, keys):
            m = leaf.shape[0]
            e = leaf.astype(jnp.float32) + r
            flat = e.reshape(m, -1)
            idx, val = self._select(flat, key)
            dec = self._scatter(jnp.zeros_like(flat), idx, val)
            rr = e - dec.reshape(e.shape)
            if active is not None:
                rr = _gate_tree(active, rr, r)
            wire_leaves.append({"idx": idx.astype(jnp.int32), "val": val})
            new_resid.append(rr)
        return (jax.tree.unflatten(treedef, wire_leaves),
                jax.tree.unflatten(treedef, new_resid))

    def decode(self, wire):
        metas, treedef = self._meta
        leaves = treedef.flatten_up_to(wire)
        out = []
        for w, (shape, dtype) in zip(leaves, metas):
            m = shape[0]
            n = int(np.prod(shape[1:], dtype=np.int64)) if len(shape) > 1 else 1
            flat = self._scatter(jnp.zeros((m, n), jnp.float32),
                                 w["idx"], w["val"])
            out.append(flat.reshape(shape).astype(dtype))
        return jax.tree.unflatten(treedef, out)


class TopKCodec(_SparseCodec):
    """Magnitude top-k sparsification with error feedback.

    Per client and per leaf the ``k`` largest-|.| entries of the
    error-compensated message go on the wire as (index, value) pairs;
    everything else accumulates into the residual.
    """

    def __init__(self, k: int = 64):
        super().__init__(k)
        self.name = f"topk[{k}]"

    def _select(self, flat, key):
        k = min(self.k, flat.shape[1])
        _, idx = jax.lax.top_k(jnp.abs(flat), k)
        return idx, jnp.take_along_axis(flat, idx, axis=1)

    def bytes_per_client(self, params_single: PyTree) -> int:
        total = 0
        for leaf in jax.tree.leaves(params_single):
            k = min(self.k, leaf.size)
            total += k * (4 + 4)                   # int32 index + f32 value
        return int(total)


class RandKCodec(_SparseCodec):
    """Random-k sparsification with error feedback.

    Per leaf, ``k`` coordinates are drawn uniformly each round from the
    round's shared codec PRNG — the SAME indices for every client, so
    the decoded messages stay mixable and, unlike top-k, no per-client
    magnitude sort runs on the accelerator (rand-k is the cheap
    sparsifier on TPU: one gather vs a full ``top_k``).  Only the values
    go on the wire; receivers regenerate the indices from the shared
    round seed, so the modeled message is ~half a top-k message at equal
    ``k``.  The skipped mass accumulates in the same per-client
    error-feedback residual state the other lossy codecs use
    (``DFLState.comm["residual"]``).
    """

    def __init__(self, k: int = 64):
        super().__init__(k)
        self.name = f"randk[{k}]"

    def encode(self, z, resid=None, rng=None, active=None):
        if rng is None:
            raise ValueError("randk needs the round's codec PRNG key "
                             "(clients must agree on the sampled indices)")
        return super().encode(z, resid, rng, active)

    def _select(self, flat, key):
        n = flat.shape[1]
        k = min(self.k, n)
        idx = jax.random.choice(key, n, shape=(k,), replace=False)
        return idx, flat[:, idx]

    def bytes_per_client(self, params_single: PyTree) -> int:
        # values only: the indices are regenerated from the shared round
        # seed (modeled as one 4-byte seed per leaf)
        total = 0
        for leaf in jax.tree.leaves(params_single):
            total += min(self.k, leaf.size) * 4 + 4
        return int(total)


def can_fuse_dense(transport: Transport, codec: MessageCodec) -> bool:
    """True when this transport/codec pair takes the fused quantized-
    gossip kernel: a ``DenseTransport`` plan is the (m, m) matrix itself,
    so ``QuantizeCodec.encode_mix_dense`` can collapse encode -> decode
    -> mix into one Pallas kernel per leaf (gated by ``use_kernel``).
    Other transports (gated permutes, push-sum weight algebra) keep the
    composed path."""
    return (isinstance(transport, DenseTransport)
            and isinstance(codec, QuantizeCodec) and codec.use_kernel)


# user-registered codec factories (register_codec); the builtin names in
# ``CODECS`` are resolved by the if-chain in make_codec
_CODEC_REGISTRY = FactoryRegistry("codec", CODECS)


def register_codec(name: str, factory, overwrite: bool = False) -> None:
    """Register ``factory(cfg) -> MessageCodec`` under ``name``.

    Mirrors ``solvers.register_solver``: once registered the codec is
    selectable via ``DFLConfig(codec=name)`` (config validation resolves
    through :func:`codec_names`) with no round-loop changes.  ``cfg`` is
    the full config, so factories may read ``codec_bits`` / ``codec_k``
    / any field they need.
    """
    _CODEC_REGISTRY.register(name, factory, overwrite)


def codec_names() -> tuple[str, ...]:
    """All selectable codec names: builtins plus registered ones."""
    return _CODEC_REGISTRY.names()


def make_codec(cfg) -> MessageCodec:
    """Build the codec named by ``cfg.codec`` (builtin or registered)."""
    name = cfg.codec
    if name in _CODEC_REGISTRY:
        return _CODEC_REGISTRY.build(name, cfg)
    if name == "identity":
        return IdentityCodec()
    if name == "int8":
        uk = getattr(cfg, "use_kernel", False)
        return QuantizeCodec(bits=cfg.codec_bits,
                             use_kernel=uk is True or uk == "comm")
    if name == "fp8":
        return Fp8Codec()
    if name == "topk":
        return TopKCodec(k=cfg.codec_k)
    if name == "randk":
        return RandKCodec(k=cfg.codec_k)
    if name == "dp":
        # the privacy wire lives with the rest of the adversarial layer
        # (import deferred: threat.py imports this module)
        from repro.core.threat import DPCodec
        return DPCodec(clip=getattr(cfg, "dp_clip", 1.0),
                       noise=getattr(cfg, "dp_noise", 0.0))
    raise ValueError(
        f"unknown codec {name!r}; expected one of {codec_names()}")


def init_comm_state(cfg, stacked_params: PyTree):
    """Per-client communication state threaded through ``DFLState.comm``:
    push-sum weights, error-feedback residuals, and/or the tracking
    buffer of a variance-reduction solver, or None when every layer is
    stateless (the seed layout, bit-compatible).

    State shapes are owned by the codec (``init_state``), transport
    (``init_aux``), and solver (``init_track``); this only decides which
    slots exist."""
    comm = {}
    if cfg.transport == "pushsum":
        comm["ps_weight"] = PushSumTransport().init_aux(cfg.m)
    codec = make_codec(cfg)
    if codec.stateful:
        comm["residual"] = codec.init_state(stacked_params)
    # solvers with a gossip-carried tracking variable (SCAFFOLD control
    # variates / gradient tracking) own a second message slot, mixed by
    # the round loop through the same transport as z (import deferred:
    # solvers.py does not import this module, so no cycle)
    from repro.core import solvers as solvers_lib
    solver = solvers_lib.make_solver(cfg)
    if solver.tracks:
        comm["track"] = solver.init_track(cfg, stacked_params)
    return comm or None
