"""llama3-8b — dense GQA, 128k vocab [arXiv:2407.21783]."""
from repro.configs._helpers import reduce_for_smoke
from repro.configs.base import ArchBundle, ModelConfig, ParallelConfig

MODEL = ModelConfig(
    name="llama3-8b", arch_type="dense", num_layers=32, d_model=4096,
    num_heads=32, num_kv_heads=8, d_ff=14336, vocab_size=128256,
    head_dim=128, rope_theta=5e5, source="arXiv:2407.21783",
)
CONFIG = ArchBundle(model=MODEL, parallel=ParallelConfig())


def smoke_config() -> ModelConfig:
    return reduce_for_smoke(MODEL)
