"""Shared helpers for per-architecture config modules."""
from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig


def reduce_for_smoke(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Reduced same-family variant: 2 layers, d_model<=512, <=4 experts,
    small vocab — runs a forward/train step on CPU in seconds."""
    heads = min(cfg.num_heads, 4) if cfg.num_heads else 0
    kv = max(1, min(cfg.num_kv_heads, heads)) if heads else 0
    if heads and cfg.num_kv_heads and cfg.num_heads // cfg.num_kv_heads > 1:
        kv = max(1, heads // max(1, cfg.num_heads // cfg.num_kv_heads))
    upd = dict(
        num_layers=2,
        d_model=min(cfg.d_model, 256),
        num_heads=heads,
        num_kv_heads=kv,
        d_ff=min(cfg.d_ff, 512) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 1024),
        head_dim=min(cfg.resolved_head_dim, 64) if heads else 0,
        num_experts=min(cfg.num_experts, 4) if cfg.num_experts else 0,
        experts_per_token=min(cfg.experts_per_token, 2)
        if cfg.experts_per_token else 0,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_head_dim=min(cfg.ssm_head_dim, 16),
        ssm_chunk=16,
        sliding_window=min(cfg.sliding_window, 16) if cfg.sliding_window else 0,
        prefix_tokens=min(cfg.prefix_tokens, 8) if cfg.prefix_tokens else 0,
        hybrid_attn_every=min(cfg.hybrid_attn_every, 1)
        if cfg.hybrid_attn_every else 0,
        dtype="float32",
        loss_chunk=0,
    )
    if cfg.local_global_ratio:
        upd["num_layers"] = cfg.local_global_ratio + 1  # one full pattern
    upd.update(overrides)
    return dataclasses.replace(cfg, **upd)
