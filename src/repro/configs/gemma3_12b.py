"""gemma3-12b — 5:1 local:global attention, 256k vocab, head_dim 256
[hf:google/gemma-3-1b-pt family]."""
from repro.configs._helpers import reduce_for_smoke
from repro.configs.base import ArchBundle, ModelConfig, ParallelConfig

MODEL = ModelConfig(
    name="gemma3-12b", arch_type="dense", num_layers=48, d_model=3840,
    num_heads=16, num_kv_heads=8, d_ff=15360, vocab_size=262144,
    head_dim=256, rope_theta=1e6, sliding_window=1024, local_global_ratio=5,
    source="hf:google/gemma-3-1b-pt",
)
CONFIG = ArchBundle(model=MODEL, parallel=ParallelConfig())


def smoke_config() -> ModelConfig:
    return reduce_for_smoke(MODEL)
