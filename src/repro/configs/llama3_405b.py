"""llama3-405b — 126-layer dense GQA flagship [arXiv:2407.21783].

At m=16 DFL replicas this cannot fit one v5e pod (see EXPERIMENTS.md
§Roofline); the multi-pod client_axis="pod" + FSDP variant is the
deployable configuration (§Perf)."""
from repro.configs._helpers import reduce_for_smoke
from repro.configs.base import ArchBundle, ModelConfig, ParallelConfig

MODEL = ModelConfig(
    name="llama3-405b", arch_type="dense", num_layers=126, d_model=16384,
    num_heads=128, num_kv_heads=8, d_ff=53248, vocab_size=128256,
    head_dim=128, rope_theta=5e5, source="arXiv:2407.21783",
)
CONFIG = ArchBundle(model=MODEL, parallel=ParallelConfig(remat=True))


def smoke_config() -> ModelConfig:
    return reduce_for_smoke(MODEL)
