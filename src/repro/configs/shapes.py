"""The four assigned input shapes and ``input_specs`` builders.

Decode shapes lower ``serve_step`` (one token + KV/SSM cache); training
shapes lower the DFL ``train_round`` (the paper's technique).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ParallelConfig
from repro.models import model as model_lib


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _token_batch_specs(cfg: ModelConfig, batch: int, seq: int, *,
                       lead: tuple = ()) -> dict:
    """ShapeDtypeStruct stand-ins for one model batch (weak-type correct)."""
    specs: dict = {}
    emb_dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    if cfg.arch_type == "audio":
        specs["embeds"] = _sds(lead + (batch, seq, cfg.d_model), emb_dtype)
        specs["labels"] = _sds(lead + (batch, seq), jnp.int32)
        return specs
    ntok = seq - cfg.prefix_tokens
    specs["tokens"] = _sds(lead + (batch, ntok), jnp.int32)
    specs["labels"] = _sds(lead + (batch, ntok), jnp.int32)
    if cfg.arch_type == "vlm":
        specs["embeds"] = _sds(lead + (batch, cfg.prefix_tokens, cfg.d_model),
                               emb_dtype)
    return specs


def train_input_specs(cfg: ModelConfig, par: ParallelConfig,
                      shape: InputShape) -> dict:
    """DFL training batch: leaves (m, K, b_local, ...)."""
    m, K = par.dfl_m, par.dfl_k
    if shape.global_batch % m:
        raise ValueError(f"global_batch {shape.global_batch} not divisible "
                         f"by m={m}")
    b_local = shape.global_batch // m
    return _token_batch_specs(cfg, b_local, shape.seq_len, lead=(m, K))


def prefill_input_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    return _token_batch_specs(cfg, shape.global_batch, shape.seq_len)


def decode_input_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    """One new token + a cache covering ``seq_len`` positions."""
    b = shape.global_batch
    emb_dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    if cfg.arch_type == "audio":
        token = _sds((b, 1, cfg.d_model), emb_dtype)
    else:
        token = _sds((b,), jnp.int32)
    cache = model_lib.cache_shapes(cfg, b, shape.seq_len)
    return {"token": token, "cache": cache}


def input_specs(cfg: ModelConfig, par: ParallelConfig, shape_name: str) -> dict:
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        return train_input_specs(cfg, par, shape)
    if shape.kind == "prefill":
        return prefill_input_specs(cfg, shape)
    return decode_input_specs(cfg, shape)


def shape_applicable(cfg: ModelConfig, shape_name: str) -> tuple[bool, str]:
    """(runs?, reason) — long_500k is skipped for pure full-attention archs
    per DESIGN.md §5."""
    if shape_name == "long_500k" and not cfg.sub_quadratic:
        return False, ("pure full-attention architecture: no sub-quadratic "
                       "variant published; skipped per spec")
    return True, ""
