"""qwen3-moe-235b-a22b — 128-expert top-8 MoE [hf:Qwen/Qwen3-30B-A3B
family].  Experts sharded over the ``model`` axis (8 experts/device);
d_ff=1536 stays unsharded."""
from repro.configs._helpers import reduce_for_smoke
from repro.configs.base import ArchBundle, ModelConfig, ParallelConfig

MODEL = ModelConfig(
    name="qwen3-moe-235b-a22b", arch_type="moe", num_layers=94, d_model=4096,
    num_heads=64, num_kv_heads=4, d_ff=1536, vocab_size=151936,
    head_dim=128, rope_theta=1e6, num_experts=128, experts_per_token=8,
    expert_sharding="expert", source="hf:Qwen/Qwen3-30B-A3B",
)
CONFIG = ArchBundle(model=MODEL, parallel=ParallelConfig(remat=True))


def smoke_config() -> ModelConfig:
    return reduce_for_smoke(MODEL)
