"""falcon-mamba-7b — attention-free Mamba-1 [arXiv:2410.05355]."""
from repro.configs._helpers import reduce_for_smoke
from repro.configs.base import ArchBundle, ModelConfig, ParallelConfig

MODEL = ModelConfig(
    name="falcon-mamba-7b", arch_type="ssm", num_layers=64, d_model=4096,
    d_ff=0, vocab_size=65024, ssm_variant="mamba1", ssm_state=16,
    expand=2, d_conv=4, ssm_chunk=256, source="arXiv:2410.05355",
)
CONFIG = ArchBundle(model=MODEL, parallel=ParallelConfig())


def smoke_config() -> ModelConfig:
    return reduce_for_smoke(MODEL)
