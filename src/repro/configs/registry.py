"""--arch <id> resolution for launchers, tests and benchmarks."""
from __future__ import annotations

import importlib

from repro.configs.base import ArchBundle, ModelConfig

ARCH_IDS = (
    "minitron-8b", "musicgen-large", "llama3-8b", "falcon-mamba-7b",
    "mixtral-8x7b", "llama3-405b", "gemma3-12b", "zamba2-1.2b",
    "paligemma-3b", "qwen3-moe-235b-a22b",
)

_MODULES = {
    "minitron-8b": "minitron_8b",
    "musicgen-large": "musicgen_large",
    "llama3-8b": "llama3_8b",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "mixtral-8x7b": "mixtral_8x7b",
    "llama3-405b": "llama3_405b",
    "gemma3-12b": "gemma3_12b",
    "zamba2-1.2b": "zamba2_1p2b",
    "paligemma-3b": "paligemma_3b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
}


def _module(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")


def get_bundle(arch_id: str) -> ArchBundle:
    return _module(arch_id).CONFIG


def get_model_config(arch_id: str) -> ModelConfig:
    return get_bundle(arch_id).model


def get_smoke_config(arch_id: str) -> ModelConfig:
    return _module(arch_id).smoke_config()
