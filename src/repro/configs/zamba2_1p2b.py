"""zamba2-1.2b — Mamba-2 backbone with a shared attention block applied
every 6 layers [arXiv:2411.15242]."""
from repro.configs._helpers import reduce_for_smoke
from repro.configs.base import ArchBundle, ModelConfig, ParallelConfig

MODEL = ModelConfig(
    name="zamba2-1.2b", arch_type="hybrid", num_layers=38, d_model=2048,
    num_heads=32, num_kv_heads=32, d_ff=8192, vocab_size=32000,
    rope_theta=1e4, ssm_variant="mamba2", ssm_state=64, ssm_head_dim=64,
    expand=2, d_conv=4, ssm_chunk=256, hybrid_attn_every=6,
    source="arXiv:2411.15242",
)
CONFIG = ArchBundle(model=MODEL, parallel=ParallelConfig())


def smoke_config() -> ModelConfig:
    return reduce_for_smoke(MODEL)
