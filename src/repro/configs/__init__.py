from repro.configs.base import ArchBundle, ModelConfig, ParallelConfig
from repro.configs.registry import (ARCH_IDS, get_bundle, get_model_config,
                                    get_smoke_config)
from repro.configs.shapes import (SHAPES, InputShape, input_specs,
                                  shape_applicable)
