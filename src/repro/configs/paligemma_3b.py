"""paligemma-3b — SigLIP + Gemma VLM [arXiv:2407.07726].

The SigLIP vision tower + projector is a stub per the assignment:
``input_specs`` supplies 256 precomputed patch embeddings (B, 256,
d_model) which attend bidirectionally (prefix-LM mask)."""
from repro.configs._helpers import reduce_for_smoke
from repro.configs.base import ArchBundle, ModelConfig, ParallelConfig

MODEL = ModelConfig(
    name="paligemma-3b", arch_type="vlm", num_layers=18, d_model=2048,
    num_heads=8, num_kv_heads=1, d_ff=16384, vocab_size=257216,
    head_dim=256, rope_theta=1e4, prefix_tokens=256, frontend="vision",
    source="arXiv:2407.07726",
)
CONFIG = ArchBundle(model=MODEL, parallel=ParallelConfig())


def smoke_config() -> ModelConfig:
    return reduce_for_smoke(MODEL)
