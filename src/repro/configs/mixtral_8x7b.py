"""mixtral-8x7b — 8-expert top-2 MoE with sliding-window attention
[arXiv:2401.04088].  Experts use per-expert tensor parallelism."""
from repro.configs._helpers import reduce_for_smoke
from repro.configs.base import ArchBundle, ModelConfig, ParallelConfig

MODEL = ModelConfig(
    name="mixtral-8x7b", arch_type="moe", num_layers=32, d_model=4096,
    num_heads=32, num_kv_heads=8, d_ff=14336, vocab_size=32000,
    head_dim=128, rope_theta=1e6, sliding_window=4096,
    num_experts=8, experts_per_token=2, expert_sharding="tensor",
    source="arXiv:2401.04088",
)
CONFIG = ArchBundle(model=MODEL, parallel=ParallelConfig())


def smoke_config() -> ModelConfig:
    return reduce_for_smoke(MODEL)
