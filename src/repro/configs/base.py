"""Config system: model architecture + parallelism + DFL hyperparameters.

Every assigned architecture gets one module in this package exporting
``CONFIG`` (the full published size) and ``smoke_config()`` (a reduced
2-layer variant for CPU tests).  ``repro.configs.registry`` resolves
``--arch <id>`` strings.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str               # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int = 0           # 0 for attention-free archs
    num_kv_heads: int = 0
    d_ff: int = 0
    vocab_size: int = 32000
    head_dim: int = 0            # 0 -> d_model // num_heads
    source: str = ""             # citation bracket from the assignment

    # attention flavour
    rope_theta: float = 500000.0
    sliding_window: int = 0      # 0 -> full attention
    local_global_ratio: int = 0  # gemma3: N local layers per 1 global
    prefix_tokens: int = 0       # VLM: bidirectional prefix length (patches)

    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_aux_coef: float = 0.01
    capacity_factor: float = 1.25
    moe_group_size: int = 4096   # GShard token-group size (0 -> one group)
    expert_sharding: str = "tensor"   # "tensor" (shard d_ff) | "expert" (shard E)

    # SSM
    ssm_variant: str = ""        # "mamba1" | "mamba2"
    ssm_kernel: bool = False     # route mamba1 prefill through the fused
                                 # Pallas selective-scan (serving path;
                                 # no VJP — training uses chunked_ssm)
    ssm_state: int = 0
    d_conv: int = 4
    expand: int = 2
    ssm_head_dim: int = 64       # mamba2
    ssm_chunk: int = 128         # chunked selective-scan length

    # hybrid (zamba2-style): shared attention block every N ssm layers
    hybrid_attn_every: int = 0

    # modality frontend stub ("" | "audio" | "vision")
    frontend: str = ""

    dtype: str = "bfloat16"
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    loss_chunk: int = 0          # 0 -> full-logit CE; >0 -> chunked CE

    def __post_init__(self):
        if self.arch_type not in ("dense", "moe", "ssm", "hybrid", "vlm", "audio"):
            raise ValueError(f"bad arch_type {self.arch_type!r}")
        if self.arch_type in ("dense", "moe", "vlm", "audio") and self.num_heads <= 0:
            raise ValueError(f"{self.name}: attention archs need num_heads")
        if self.arch_type == "moe" and self.num_experts <= 0:
            raise ValueError(f"{self.name}: moe needs experts")

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return math.ceil(self.d_model / 16)

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def uses_attention(self) -> bool:
        return self.arch_type in ("dense", "moe", "vlm", "audio", "hybrid")

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k shape (per DESIGN.md §5)."""
        return (self.arch_type in ("ssm", "hybrid")
                or self.sliding_window > 0 or self.local_global_ratio > 0)

    def param_count(self) -> int:
        """Analytic parameter count (used by config sanity tests)."""
        d, v, L = self.d_model, self.vocab_size, self.num_layers
        total = v * d                      # embed
        if not self.tie_embeddings:
            total += d * v                 # lm_head
        hd = self.resolved_head_dim
        per_layer = 0
        if self.arch_type in ("dense", "moe", "vlm", "audio"):
            qkv = d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd
            per_layer += qkv + self.num_heads * hd * d + 2 * d   # attn + norms
            if self.arch_type == "moe":
                per_layer += d * self.num_experts                # router
                per_layer += self.num_experts * 3 * d * self.d_ff
            else:
                per_layer += 3 * d * self.d_ff
        elif self.arch_type == "ssm":
            di, st, dr = self.d_inner, self.ssm_state, self.dt_rank
            per_layer += d * 2 * di + self.d_conv * di + di       # in_proj+conv
            per_layer += di * (dr + 2 * st) + dr * di + di        # x_proj,dt
            per_layer += di * st + di + di * d + d                # A,D,out,norm
        elif self.arch_type == "hybrid":
            di, st = self.d_inner, self.ssm_state
            nh = self.ssm_heads
            per_layer += d * (2 * di + 2 * st + nh) + self.d_conv * di + di
            per_layer += 2 * nh + di * d + d + di                 # A,D,out,norms
        total += L * per_layer
        if self.arch_type == "hybrid" and self.hybrid_attn_every:
            qkv = d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd
            total += qkv + self.num_heads * hd * d + 3 * d * self.d_ff + 2 * d
        if self.arch_type == "audio":
            total -= v * d  # no input embedding table (frame embeds from stub)
        return total

    def active_param_count(self) -> int:
        """MoE: params touched per token (for MODEL_FLOPS = 6·N_active·D)."""
        if self.arch_type != "moe":
            return self.param_count()
        d, L = self.d_model, self.num_layers
        dense = self.param_count() - L * self.num_experts * 3 * d * self.d_ff
        return dense + L * self.experts_per_token * 3 * d * self.d_ff


@dataclasses.dataclass(frozen=True)
class ParallelConfig:
    """How a config maps onto the production mesh."""
    client_axis: str = "data"    # DFL client axis: "data" (m=16) or "pod" (m=2)
    batch_axes: tuple = ("pod",)  # per-client batch data-parallel axes
    tensor_axis: str = "model"
    fsdp_axis: str = ""          # optional param sharding axis within client
    dfl_m: int = 16
    dfl_k: int = 2               # inner steps lowered in the dry-run
    microbatches: int = 1        # grad-accum splits per inner step
    mixing: str = "dense"
    topology: str = "ring"
    remat: bool = False          # activation checkpointing per layer

    # client participation scenario (repro.core.participation); the
    # defaults are the paper's full-participation setting
    participation_mode: str = "full"   # full | uniform | fraction | schedule
    participation_p: float = 1.0       # sampling prob / kept fraction
    dropout: float = 0.0               # P(sampled client crashes mid-round)
    straggler_frac: float = 0.0        # fixed fraction of slow clients
    straggler_steps: int = 1           # local steps a straggler completes
    min_active: int = 2                # floor on sampled clients per round

    def participation_spec(self, seed: int = 0):
        """Materialize the scenario as a ``ParticipationSpec`` (lazy import
        keeps this config module free of core dependencies).

        Deterministic ``schedule`` mode is not expressible here — a
        schedule is a per-round tuple of client ids, not a flat config
        field; build the spec directly for that.  These knobs describe
        the single-device simulation substrate; the sharded dry-run path
        does not consume them yet (see ROADMAP open items)."""
        from repro.core.participation import ParticipationSpec
        return ParticipationSpec(mode=self.participation_mode,
                                 p=self.participation_p,
                                 dropout=self.dropout,
                                 straggler_frac=self.straggler_frac,
                                 straggler_steps=self.straggler_steps,
                                 min_active=self.min_active,
                                 seed=seed)


@dataclasses.dataclass(frozen=True)
class ArchBundle:
    model: ModelConfig
    parallel: ParallelConfig = ParallelConfig()
    extra: dict[str, Any] = dataclasses.field(default_factory=dict)
