"""musicgen-large — decoder-only LM over EnCodec audio tokens
[arXiv:2306.05284].  The EnCodec/conv frontend is a stub: ``input_specs``
supplies precomputed frame embeddings (B, S, d_model)."""
from repro.configs._helpers import reduce_for_smoke
from repro.configs.base import ArchBundle, ModelConfig, ParallelConfig

MODEL = ModelConfig(
    name="musicgen-large", arch_type="audio", num_layers=48, d_model=2048,
    num_heads=32, num_kv_heads=32, d_ff=8192, vocab_size=2048,
    rope_theta=1e4, frontend="audio", source="arXiv:2306.05284",
)
CONFIG = ArchBundle(model=MODEL, parallel=ParallelConfig())


def smoke_config() -> ModelConfig:
    return reduce_for_smoke(MODEL)
