"""minitron-8b — width-pruned Nemotron-4 15B [arXiv:2407.14679]."""
from repro.configs._helpers import reduce_for_smoke
from repro.configs.base import ArchBundle, ModelConfig, ParallelConfig

MODEL = ModelConfig(
    name="minitron-8b", arch_type="dense", num_layers=32, d_model=4096,
    num_heads=32, num_kv_heads=8, d_ff=16384, vocab_size=256000,
    head_dim=128, rope_theta=1e4, source="arXiv:2407.14679",
)
CONFIG = ArchBundle(model=MODEL, parallel=ParallelConfig())


def smoke_config() -> ModelConfig:
    return reduce_for_smoke(MODEL)
