"""Multi-pod dry-run machinery (import-safe: no env mutation here).

For every (architecture x input shape x mesh) combination we build the
step function with its shardings, ``.lower().compile()`` it AOT against
ShapeDtypeStruct stand-ins (no allocation), and extract:

  * memory_analysis()  — per-device bytes (proves fit / measures overflow)
  * cost_analysis()    — HLO FLOPs + bytes accessed (roofline terms 1-2)
  * the collective schedule parsed from the optimized HLO (term 3)

Variants (the §Perf levers) are expressed as ``DryrunVariant`` overrides.
"""
from __future__ import annotations

import dataclasses
import json
import os
import re
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_bundle, input_specs, shape_applicable
from repro.configs.base import ModelConfig, ParallelConfig
from repro.configs.shapes import SHAPES
from repro.core import DFLConfig, make_gossip, make_train_round
from repro.core import dfl as dfl_lib
from repro.launch import mesh as mesh_lib
from repro.models import model as model_lib
from repro.sharding import partition

PyTree = Any

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "experiments", "artifacts")


@dataclasses.dataclass(frozen=True)
class DryrunVariant:
    """A named configuration point for the §Perf hillclimb."""
    name: str = "baseline"
    mixing: str = "dense"            # dense | ppermute
    topology: str = "ring"
    client_axis: str = ""            # "" -> bundle default
    fsdp_axis: str = ""
    dfl_m: int = 0                   # 0 -> bundle default
    dfl_k: int = 0
    microbatches: int = 0            # 0 -> bundle default
    loss_chunk: int = -1             # -1 -> config default
    remat: bool | None = None
    flash_decode: bool = False       # shard_map flash decode (long ctx)
    kv_shard: str = ""               # "" | "hd" | "heads" | "seq" (decode cache)
    metrics: str = "full"            # "full" | "light" (see core.dfl)
    extra: dict = dataclasses.field(default_factory=dict)


def resolve(arch_id: str, variant: DryrunVariant,
            multi_pod: bool) -> tuple[ModelConfig, ParallelConfig]:
    bundle = get_bundle(arch_id)
    cfg, par = bundle.model, bundle.parallel
    upd: dict = {}
    if variant.client_axis:
        upd["client_axis"] = variant.client_axis
        if variant.client_axis == "pod":
            # clients = pods (giant-model layout): per-client batch is
            # data-parallel over the freed "data" axis instead.
            upd["batch_axes"] = ("data",)
    if variant.fsdp_axis:
        upd["fsdp_axis"] = variant.fsdp_axis
    if variant.dfl_m:
        upd["dfl_m"] = variant.dfl_m
    if variant.dfl_k:
        upd["dfl_k"] = variant.dfl_k
    if variant.microbatches:
        upd["microbatches"] = variant.microbatches
    if variant.remat is not None:
        upd["remat"] = variant.remat
    upd["mixing"] = variant.mixing
    upd["topology"] = variant.topology
    par = dataclasses.replace(par, **upd)
    if variant.loss_chunk >= 0:
        cfg = dataclasses.replace(cfg, loss_chunk=variant.loss_chunk)
    if not multi_pod:
        # no pod axis on the single-pod mesh
        if par.client_axis == "pod":
            raise ValueError("client_axis='pod' requires the multi-pod mesh")
        par = dataclasses.replace(
            par, batch_axes=tuple(a for a in par.batch_axes if a != "pod"))
    return cfg, par


# ---------------------------------------------------------------------------
# Step builders (lowered, never executed at production size)
# ---------------------------------------------------------------------------

def build_train_step(cfg: ModelConfig, par: ParallelConfig, mesh,
                     shape_name: str, metrics: str = "full"):
    """DFL train round (the paper's technique) ready to lower."""
    m = par.dfl_m
    spec = make_gossip(par.topology, m)
    dfl_cfg = DFLConfig(algorithm="dfedadmm", m=m, K=par.dfl_k,
                        topology=par.topology, transport=par.mixing,
                        microbatches=par.microbatches)

    def loss_fn(params, batch, rng):
        return model_lib.loss_fn(params, cfg, batch, rng, remat=par.remat)

    param_sh = model_lib.param_shapes(cfg)
    pspecs = partition.param_specs(param_sh, cfg, par, stacked_client=True)
    round_fn = make_train_round(
        loss_fn, dfl_cfg, spec=spec, mesh=mesh,
        client_axis=par.client_axis, param_inner_specs=pspecs,
        metrics=metrics)

    # the solver allocates its own state slot: abstractly evaluate
    # init_state so the stand-in tree matches whatever the algorithm's
    # LocalSolver carries (dual for ADMM, nothing for SGD, ...)
    state_sds = jax.eval_shape(
        lambda p: dfl_lib.init_state(p, dfl_cfg, seed=0), param_sh)
    batch_sds = input_specs(cfg, par, shape_name)
    w_sds = jax.ShapeDtypeStruct((m, m), jnp.float32)

    state_specs = partition.dfl_state_specs(param_sh, cfg, par,
                                            algorithm=dfl_cfg.algorithm)
    batch_specs = partition.train_batch_specs(batch_sds, par)
    in_shardings = (partition.to_shardings(state_specs, mesh),
                    partition.to_shardings(batch_specs, mesh),
                    NamedSharding(mesh, P()))
    out_shardings = (partition.to_shardings(state_specs, mesh), None)
    jitted = jax.jit(round_fn, in_shardings=in_shardings,
                     out_shardings=out_shardings)
    return jitted, (state_sds, batch_sds, w_sds)


def build_prefill_step(cfg: ModelConfig, par: ParallelConfig, mesh,
                       shape_name: str, multi_pod: bool):
    shape = SHAPES[shape_name]

    def prefill_step(params, batch):
        return model_lib.prefill(params, cfg, batch, shape.seq_len)

    param_sh = model_lib.param_shapes(cfg)
    pspecs = partition.param_specs(param_sh, cfg, par)
    batch_sds = input_specs(cfg, par, shape_name)
    bspecs = partition.prefill_batch_specs(batch_sds, par, multi_pod)
    in_shardings = (partition.to_shardings(pspecs, mesh),
                    partition.to_shardings(bspecs, mesh))
    jitted = jax.jit(prefill_step, in_shardings=in_shardings)
    return jitted, (param_sh, batch_sds)


def build_decode_step(cfg: ModelConfig, par: ParallelConfig, mesh,
                      shape_name: str, multi_pod: bool,
                      flash_decode: bool = False, kv_shard: str = ""):
    long_ctx = shape_name == "long_500k"
    flash_axis = "data" if (flash_decode and long_ctx) else None

    def serve_step(params, cache, token):
        return model_lib.decode_step(params, cfg, cache, token, mesh=mesh,
                                     flash_axis=flash_axis)

    param_sh = model_lib.param_shapes(cfg)
    pspecs = partition.param_specs(param_sh, cfg, par)
    io_sds = input_specs(cfg, par, shape_name)
    io_specs = partition.decode_specs(io_sds, cfg, par, multi_pod,
                                      long_context=long_ctx,
                                      kv_shard=kv_shard)
    in_shardings = (partition.to_shardings(pspecs, mesh),
                    partition.to_shardings(io_specs["cache"], mesh),
                    partition.to_shardings(io_specs["token"], mesh))
    out_shardings = (None, partition.to_shardings(io_specs["cache"], mesh))
    jitted = jax.jit(serve_step, in_shardings=in_shardings,
                     out_shardings=out_shardings)
    return jitted, (param_sh, io_sds["cache"], io_sds["token"])


# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# link-traffic multiplier applied to the RESULT bytes of each collective
# (ring-algorithm per-device traffic; documented in EXPERIMENTS.md §Roofline)
_LINK_FACTOR = {
    "all-gather": 1.0,        # receives (N-1)/N of the result ~ 1x
    "all-reduce": 2.0,        # reduce-scatter + all-gather
    "reduce-scatter": 1.0,    # sends ~operand/N * (N-1) ~ result x 1
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\([^)]*\)\s*->.*{\s*$")
_WHILE_BODY_RE = re.compile(r"\bwhile\(.*?body=%?([\w.\-]+)")
_CALL_RE = re.compile(
    r"\b(?:body|condition|to_apply|branch_computations=\{)[=\s]*%?"
    r"([\w.\-]+)")


def _shape_bytes(shape_text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _split_computations(hlo_text: str) -> dict[str, list[str]]:
    """computation name -> its instruction lines (flat brace matching)."""
    comps: dict[str, list[str]] = {}
    current = None
    for line in hlo_text.splitlines():
        m = _COMP_RE.match(line.strip())
        if m and line.rstrip().endswith("{"):
            current = m.group(1)
            comps[current] = []
            continue
        if current is not None:
            if line.strip() == "}":
                current = None
            else:
                comps[current].append(line)
    return comps


def parse_collectives(hlo_text: str, scan_trips: list[int] | None = None
                      ) -> dict:
    """Sum result bytes per collective type over the optimized HLO.

    XLA cost/byte analyses count ``while`` bodies ONCE, so collectives
    inside scan loops (TP all-reduces per layer, K inner steps) are
    undercounted.  ``scan_trips`` gives the trip counts of the scan
    nest from outermost to innermost (e.g. [K, L] for the DFL train
    round, [L] for prefill/decode); a collective found inside n nested
    while bodies is multiplied by the product of the first n trips.
    """
    comps = _split_computations(hlo_text)

    # map: body computation name -> the computation containing its while op
    body_parent: dict[str, str] = {}
    for cname, lines in comps.items():
        for line in lines:
            if " while(" in line or "=while(" in line:
                mb = _WHILE_BODY_RE.search(line)
                if mb:
                    body_parent[mb.group(1)] = cname

    # call edges (fusion/to_apply/cond branches) to propagate depth into
    # called computations
    called_by: dict[str, str] = {}
    for cname, lines in comps.items():
        for line in lines:
            for m in _CALL_RE.finditer(line):
                callee = m.group(1)
                if callee in comps and callee not in body_parent:
                    called_by.setdefault(callee, cname)

    def depth_of(cname: str, seen=None) -> int:
        seen = seen or set()
        if cname in seen:
            return 0
        seen.add(cname)
        if cname in body_parent:
            return 1 + depth_of(body_parent[cname], seen)
        if cname in called_by:
            return depth_of(called_by[cname], seen)
        return 0

    trips = scan_trips or []

    def multiplier(depth: int) -> int:
        mult = 1
        for t in trips[:depth]:
            mult *= max(int(t), 1)
        # deeper nesting than hints: assume innermost hint repeats
        if depth > len(trips) and trips:
            for _ in range(depth - len(trips)):
                mult *= max(int(trips[-1]), 1)
        return mult

    stats = {c: {"count": 0, "bytes": 0, "scaled_bytes": 0}
             for c in _COLLECTIVES}
    for cname, lines in comps.items():
        depth = depth_of(cname)
        mult = multiplier(depth)
        for line in lines:
            m = _OP_RE.search(line)
            if not m:
                continue
            shape_text, op = m.group(1), m.group(2)
            if "-done(" in line:
                continue
            b = _shape_bytes(shape_text)
            stats[op]["count"] += 1
            stats[op]["bytes"] += b
            stats[op]["scaled_bytes"] += b * mult
    stats["link_bytes"] = sum(
        int(v["scaled_bytes"] * _LINK_FACTOR[k]) for k, v in stats.items()
        if k in _LINK_FACTOR)
    stats["total_bytes"] = sum(v["bytes"] for k, v in stats.items()
                               if k in _COLLECTIVES)
    stats["total_scaled_bytes"] = sum(
        v["scaled_bytes"] for k, v in stats.items() if k in _COLLECTIVES)
    stats["scan_trips"] = list(trips)
    return stats


# ---------------------------------------------------------------------------
# Roofline terms
# ---------------------------------------------------------------------------

def roofline_terms(cost: dict, collectives: dict, n_devices: int,
                   cfg: ModelConfig, shape_name: str, kind: str,
                   dfl_m: int, dfl_k: int, *, tp_degree: int = 16,
                   cache_bytes_total: int = 0) -> dict:
    """Three roofline terms per device.

    XLA's cost_analysis counts while (scan) bodies ONCE, so measured FLOPs
    and bytes are lower bounds that undercount scanned layers.  We therefore
    report the measured values AND analytic floors, and build each term from
    max(measured, floor):
      * compute floor  — MODEL_FLOPS (6·N_active·D train / 2·N_active·D
        inference) divided across chips;
      * memory floor   — parameter (+ optimizer/dual state + KV cache)
        traffic per device per step;
      * collective     — HLO collectives with scan-nesting trip multipliers
        (see parse_collectives).
    """
    flops = float(cost.get("flops", 0.0))
    bytes_accessed = float(cost.get("bytes accessed", 0.0))
    link_bytes = float(collectives.get("link_bytes", 0))

    shape = SHAPES[shape_name]
    n_active = cfg.active_param_count()
    dtype_bytes = 2 if cfg.dtype == "bfloat16" else 4
    params_dev = cfg.param_count() * dtype_bytes / tp_degree
    if kind == "train":
        tokens = shape.global_batch * shape.seq_len * dfl_k
        model_flops = 6 * n_active * tokens
        # fwd read + bwd read per inner step, plus dual/anchor/z traffic
        mem_floor = (2 * dfl_k + 6) * params_dev
    elif kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2 * n_active * tokens
        mem_floor = params_dev + cache_bytes_total / n_devices
    else:
        tokens = shape.global_batch
        model_flops = 2 * n_active * tokens
        mem_floor = params_dev + cache_bytes_total / n_devices

    flops_floor = model_flops / n_devices
    eff_flops = max(flops, flops_floor)
    eff_bytes = max(bytes_accessed, mem_floor)

    t_compute = eff_flops / mesh_lib.PEAK_FLOPS_BF16
    t_memory = eff_bytes / mesh_lib.HBM_BW
    t_collective = link_bytes / mesh_lib.ICI_BW
    dominant = max((("compute", t_compute), ("memory", t_memory),
                    ("collective", t_collective)), key=lambda kv: kv[1])[0]
    return {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_collective,
        "dominant": dominant,
        "hlo_flops_per_device": flops,
        "hlo_bytes_per_device": bytes_accessed,
        "flops_floor_per_device": flops_floor,
        "mem_floor_per_device": mem_floor,
        "link_bytes_per_device": link_bytes,
        "model_flops": model_flops,
        "params_bytes_per_device": params_dev,
        # how much of the compiled compute is useful model math; >1 means
        # XLA's single-count of scan bodies hides recompute (see note above)
        "useful_flops_ratio": (model_flops / (eff_flops * n_devices)
                               if eff_flops else 0.0),
    }


# ---------------------------------------------------------------------------
# The dry run itself
# ---------------------------------------------------------------------------

def dryrun_one(arch_id: str, shape_name: str, *, multi_pod: bool = False,
               variant: DryrunVariant = DryrunVariant(),
               mesh=None, save: bool = True, verbose: bool = True) -> dict:
    cfg, par = resolve(arch_id, variant, multi_pod)
    ok, reason = shape_applicable(cfg, shape_name)
    record: dict = {
        "arch": arch_id, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "variant": variant.name, "status": "skipped", "reason": reason,
    }
    if not ok:
        if save:
            _save_record(record)
        if verbose:
            print(f"[dryrun] SKIP {arch_id} x {shape_name}: {reason}")
        return record

    if mesh is None:
        mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    n_devices = mesh_lib.mesh_devices(mesh)
    kind = SHAPES[shape_name].kind

    t0 = time.time()
    with jax.set_mesh(mesh):
        if kind == "train":
            jitted, args = build_train_step(cfg, par, mesh, shape_name,
                                            metrics=variant.metrics)
        elif kind == "prefill":
            jitted, args = build_prefill_step(cfg, par, mesh, shape_name,
                                              multi_pod)
        else:
            jitted, args = build_decode_step(cfg, par, mesh, shape_name,
                                             multi_pod,
                                             flash_decode=variant.flash_decode,
                                             kv_shard=variant.kv_shard)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()

    # scan-nest trip hints, outermost first (see parse_collectives)
    L = cfg.num_layers
    if kind == "train":
        trips = [par.dfl_k, L]
    else:
        trips = [L]
    if cfg.arch_type in ("ssm", "hybrid") and kind in ("train", "prefill"):
        trips.append(max(SHAPES[shape_name].seq_len // cfg.ssm_chunk, 1))

    cache_bytes_total = 0
    if kind == "decode":
        cache_tree = args[1]
        cache_bytes_total = int(sum(
            np.prod(x.shape) * x.dtype.itemsize
            for x in jax.tree.leaves(cache_tree)))

    tp_degree = mesh.devices.shape[-1]
    coll = parse_collectives(hlo, scan_trips=trips)
    terms = roofline_terms(cost, coll, n_devices, cfg, shape_name, kind,
                           par.dfl_m, par.dfl_k, tp_degree=tp_degree,
                           cache_bytes_total=cache_bytes_total)

    record.update({
        "status": "ok",
        "kind": kind,
        "n_devices": n_devices,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        },
        "cost": {k: cost.get(k) for k in ("flops", "bytes accessed",
                                          "optimal_seconds") if k in cost},
        "collectives": coll,
        "roofline": terms,
        "param_count": cfg.param_count(),
        "active_param_count": cfg.active_param_count(),
    })
    if save:
        _save_record(record)
    if verbose:
        m = record["memory"]
        arg_gb = (m["argument_bytes"] or 0) / 1e9
        tmp_gb = (m["temp_bytes"] or 0) / 1e9
        print(f"[dryrun] OK {arch_id} x {shape_name} ({record['mesh']}, "
              f"{variant.name}): lower {t_lower:.0f}s compile {t_compile:.0f}s "
              f"args/dev {arg_gb:.2f}GB temp/dev {tmp_gb:.2f}GB "
              f"dom={terms['dominant']} "
              f"t=({terms['t_compute_s']:.3e},{terms['t_memory_s']:.3e},"
              f"{terms['t_collective_s']:.3e})s")
    return record


def _save_record(record: dict) -> None:
    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    name = (f"{record['arch']}__{record['shape']}__{record['mesh']}"
            f"__{record['variant']}.json").replace("/", "_")
    with open(os.path.join(ARTIFACT_DIR, name), "w") as f:
        json.dump(record, f, indent=1, default=str)


def load_records() -> list[dict]:
    if not os.path.isdir(ARTIFACT_DIR):
        return []
    out = []
    for fn in sorted(os.listdir(ARTIFACT_DIR)):
        if fn.endswith(".json"):
            with open(os.path.join(ARTIFACT_DIR, fn)) as f:
                out.append(json.load(f))
    return out
