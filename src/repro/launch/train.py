"""DFL training driver.

Runs DFedADMM(-SAM) (or any baseline) over a chosen architecture with the
synthetic heterogeneous LM pipeline, periodic evaluation on the client-mean
model, and checkpointing.  On CPU use ``--smoke`` (reduced config); on a
real TPU mesh the same driver scales via the sharding rules.

Example:
  PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --smoke \
      --algorithm dfedadmm_sam --rounds 30 --m 8 --k 5

Communication layer (``repro.core.comm``): ``--transport`` selects how
messages move (``dense`` einsum, ``ppermute`` neighbour exchange,
``pushsum`` for directed topologies like ``dring``) and ``--codec`` what
goes on the wire.  Compressed gossip over a one-directional ring, 4-bit
messages with error feedback (~8x less uplink than f32):

  PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --smoke \
      --topology dring --transport pushsum --codec int8 --codec-bits 4 \
      --rounds 30 --m 8 --k 5
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp


def main(argv=None) -> int:
    from repro.checkpoint import save_pytree
    from repro.configs import ARCH_IDS, get_model_config, get_smoke_config
    from repro.core import (AGGREGATORS, ATTACKS, CODECS, NETWORKS,
                            TRANSPORTS, DFLConfig, ParticipationSpec,
                            ThreatSpec, mean_params, simulate,
                            solver_names)
    from repro.models import build_model

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="llama3-8b", choices=list(ARCH_IDS))
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--algorithm", default="dfedadmm",
                    choices=sorted(solver_names("dfl")),
                    help="local solver from the repro.core.solvers registry "
                         "(dfedadmm_adaptive = per-client adaptive-lambda "
                         "penalty, FedADMM-style)")
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--m", type=int, default=8)
    ap.add_argument("--k", type=int, default=5)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=0.1)
    ap.add_argument("--lam", type=float, default=0.1)
    ap.add_argument("--rho", type=float, default=0.1)
    ap.add_argument("--adapt-mu", type=float, default=10.0,
                    help="dfedadmm_adaptive: residual-balancing margin mu "
                         "(rebalance fires when one residual exceeds mu x "
                         "the other)")
    ap.add_argument("--adapt-tau", type=float, default=2.0,
                    help="dfedadmm_adaptive: multiplicative penalty step "
                         "applied when the balance margin is crossed")
    ap.add_argument("--adapt-bound", type=float, default=8.0,
                    help="dfedadmm_adaptive: cap on the per-client penalty "
                         "scale (lam_scale stays in [1/bound, bound])")
    ap.add_argument("--topology", default="random")
    ap.add_argument("--transport", default="dense", choices=TRANSPORTS,
                    help="communication transport (pushsum for directed "
                         "topologies: dring, drandom)")
    ap.add_argument("--codec", default="identity", choices=CODECS,
                    help="wire codec for gossip messages (randk: shared-"
                         "seed random-k sparsification, cheaper than topk)")
    ap.add_argument("--codec-bits", type=int, default=8,
                    help="int8 codec: bits per value (2..8)")
    ap.add_argument("--codec-k", type=int, default=64,
                    help="topk/randk codecs: kept entries per leaf")
    ap.add_argument("--microbatches", type=int, default=1,
                    help="grad-accumulation splits per inner step")
    ap.add_argument("--network", default="", choices=("",) + NETWORKS,
                    help="per-link network cost model (repro.core.network); "
                         "records modeled round wall-clock in "
                         "history['sim_time']")
    ap.add_argument("--deadline", type=float, default=0.0,
                    help="round deadline in modeled seconds: clients whose "
                         "transfer misses it sit the round out "
                         "(participation mode 'deadline'; needs --network)")
    ap.add_argument("--execution", default="sync",
                    choices=("sync", "async"),
                    help="async: event-driven engine (repro.core."
                         "async_engine) — each client re-enters the gossip "
                         "when its own modeled compute + transfer "
                         "completes; --rounds then counts ticks "
                         "(needs --network)")
    ap.add_argument("--tick-s", type=float, default=0.02,
                    help="async: seconds of virtual time per batched tick")
    ap.add_argument("--max-staleness", type=int, default=4,
                    help="async: neighbour buffers older than this many "
                         "ticks are masked out of the mixing")
    ap.add_argument("--participation", default="full",
                    choices=("full", "uniform", "fraction"),
                    help="per-round client sampling mode (--deadline "
                         "overrides this with the network-driven mode)")
    ap.add_argument("--participation-p", type=float, default=1.0,
                    help="sampling probability / kept fraction per round")
    ap.add_argument("--dropout", type=float, default=0.0,
                    help="P(sampled client crashes mid-round)")
    ap.add_argument("--straggler-frac", type=float, default=0.0,
                    help="fixed fraction of clients that straggle")
    ap.add_argument("--straggler-steps", type=int, default=1,
                    help="local steps a straggler completes (< K)")
    ap.add_argument("--min-active", type=int, default=2,
                    help="floor on sampled clients per round (0 disables; "
                         "random modes top up to meet it)")
    ap.add_argument("--attack", default="none", choices=("none",) + ATTACKS,
                    help="Byzantine attack run by a seeded persistent "
                         "adversary set (repro.core.threat): the masked "
                         "clients corrupt their outgoing gossip messages "
                         "inside the round")
    ap.add_argument("--attack-frac", type=float, default=0.0,
                    help="adversary fraction of m (floor(frac*m) clients; "
                         "needs --attack)")
    ap.add_argument("--attack-scale", type=float, default=1.0,
                    help="attack amplification (signflip/gaussian/collude)")
    ap.add_argument("--robust", default="mean", choices=AGGREGATORS,
                    help="robust mixing at the transport level: "
                         "trimmed_mean / median / krum filter Byzantine "
                         "messages per receiver; mean is the plain "
                         "(unwrapped) gossip step")
    ap.add_argument("--dp-clip", type=float, default=1.0,
                    help="dp codec: per-client L2 clip bound (--codec dp)")
    ap.add_argument("--dp-noise", type=float, default=0.0,
                    help="dp codec: noise multiplier (std = dp_noise * "
                         "dp_clip); history records dp_clip_frac per round")
    ap.add_argument("--n-virtual", type=int, default=0,
                    help="cohort virtualization: total virtual population "
                         "(0 = fully device-resident); per-round state is "
                         "gathered for a --cohort-sized hot subset")
    ap.add_argument("--cohort", type=int, default=0,
                    help="hot cohort size with --n-virtual (overrides --m; "
                         "0 keeps --m as the cohort)")
    ap.add_argument("--clusters", type=int, default=0,
                    help="two-tier hierarchy cluster count for --transport "
                         "hier and the cluster-aware hub-and-spoke network "
                         "(0 = ~sqrt(m) heuristic for hier, classic star "
                         "for the network)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--out", default="", help="write history JSON here")
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else \
        get_model_config(args.arch)
    if cfg.arch_type in ("audio", "vlm") and not args.smoke:
        raise SystemExit("frontend-stub archs: use --smoke on CPU")

    if args.cohort and not args.n_virtual:
        raise SystemExit("--cohort needs --n-virtual (the cohort is the hot "
                         "subset of the virtual population)")
    m_eff = args.cohort if (args.n_virtual and args.cohort) else args.m
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    virt = f" n_virtual={args.n_virtual}" if args.n_virtual else ""
    print(f"[train] arch={cfg.name} algo={args.algorithm} "
          f"params={model.param_count(params):,} m={m_eff} K={args.k}{virt}")

    part_kw = dict(dropout=args.dropout,
                   straggler_frac=args.straggler_frac,
                   straggler_steps=args.straggler_steps,
                   min_active=args.min_active, seed=args.seed)
    if args.execution == "async" and not args.network:
        raise SystemExit("--execution async needs --network (the event "
                         "schedule is driven by the modeled per-client "
                         "compute + transfer times)")
    if args.deadline > 0.0:
        if not args.network:
            raise SystemExit("--deadline needs --network (the deadline is "
                             "judged against the modeled transfer times)")
        part = ParticipationSpec(mode="deadline", deadline=args.deadline,
                                 **part_kw)
    else:
        part = ParticipationSpec(mode=args.participation,
                                 p=args.participation_p, **part_kw)
    if args.attack != "none" and args.attack_frac <= 0.0:
        raise SystemExit("--attack needs --attack-frac > 0 (the fraction "
                         "of clients that turn Byzantine)")
    threat = None if args.attack == "none" else ThreatSpec(
        attack=args.attack, frac=args.attack_frac, scale=args.attack_scale,
        seed=args.seed)
    dfl_cfg = DFLConfig(algorithm=args.algorithm, m=m_eff, K=args.k,
                        lr=args.lr, lam=args.lam, rho=args.rho,
                        adapt_mu=args.adapt_mu, adapt_tau=args.adapt_tau,
                        adapt_bound=args.adapt_bound,
                        topology=args.topology,
                        transport=args.transport, codec=args.codec,
                        codec_bits=args.codec_bits, codec_k=args.codec_k,
                        microbatches=args.microbatches,
                        participation=part,
                        network=args.network or None,
                        execution=args.execution,
                        tick_s=args.tick_s if args.execution == "async"
                        else 0.0,
                        max_staleness=args.max_staleness,
                        threat=threat, robust=args.robust,
                        dp_clip=args.dp_clip, dp_noise=args.dp_noise,
                        n_virtual=args.n_virtual, clusters=args.clusters)
    sampler = _make_sampler(cfg, args, m_eff)
    eval_batch = _eval_batch(cfg, args)

    def loss_fn(p, batch, rng):
        return model.loss(p, batch, rng)

    def eval_fn(p_mean):
        return {"eval_loss": float(model.loss(p_mean, eval_batch, None))}

    t0 = time.time()
    state, history = simulate(loss_fn, eval_fn, params, dfl_cfg, sampler,
                              rounds=args.rounds, seed=args.seed,
                              eval_every=max(args.rounds // 10, 1),
                              verbose=True)
    dt = time.time() - t0
    wire_mb = sum(history["wire_bytes"]) / 1e6
    sim = (f"  sim_time={sum(history['sim_time']):.1f}s ({args.network})"
           if "sim_time" in history else "")
    if threat is not None:
        sim += (f"  adversaries={threat.n_adversaries(m_eff)}/{m_eff} "
                f"({args.attack} x{args.attack_scale:g}, "
                f"robust={args.robust})")
    if args.codec == "dp":
        import math as _math
        cf = [v for v in history["dp_clip_frac"] if not _math.isnan(v)]
        sim += (f"  dp_clip_frac={sum(cf) / max(len(cf), 1):.2f} "
                f"(noise_mult={args.dp_noise:g})")
    if args.n_virtual:
        sim += (f"  virtual={args.n_virtual} cohort={m_eff} "
                f"store_rows={history['store_touched'][-1]}")
    if args.execution == "async":
        sim += f"  ticked={sum(history['ticked']) / args.rounds:.2f}"
        if "staleness" in history:
            sim += f"  max_staleness={max(history['staleness'])}"
        if not any(history["ticked"]):
            print("[train] no client completed a round within any tick "
                  "window — raise --tick-s (or --rounds): the slowest "
                  "modeled in-link needs more virtual time than "
                  f"tick_s={args.tick_s}s per tick provides")
    print(f"[train] {args.rounds} rounds in {dt:.1f}s  "
          f"final loss={history['loss'][-1]:.4f}  "
          f"eval={history['eval'].get('eval_loss', ['n/a'])[-1]}  "
          f"uplink={wire_mb:.1f}MB ({args.codec}){sim}")

    if args.ckpt_dir:
        path = save_pytree(args.ckpt_dir, args.rounds,
                           {"mean_params": mean_params(state.params)})
        print(f"[train] checkpoint -> {path}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(history, f, indent=1)
    return 0


def _make_sampler(cfg, args, m):
    from repro.data.synthetic import make_dfl_lm_sampler, make_model_batch

    if cfg.arch_type in ("audio", "vlm"):
        def sampler(t):
            return jax.tree.map(
                jnp.asarray,
                make_model_batch(cfg, args.batch, args.seq, seed=t,
                                 lead=(m, args.k)))
        return sampler
    return make_dfl_lm_sampler(cfg, m, args.k, args.batch, args.seq,
                               seed=args.seed)


def _eval_batch(cfg, args):
    from repro.data.synthetic import make_model_batch
    return jax.tree.map(jnp.asarray,
                        make_model_batch(cfg, args.batch, args.seq, seed=999))


if __name__ == "__main__":
    raise SystemExit(main())
