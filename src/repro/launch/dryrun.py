import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run entry point.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b \
      --shape train_4k [--multi-pod] [--variant ppermute] [--all]

The two env lines above MUST stay first: jax locks the device count on
first init, and the production meshes need 512 placeholder host devices.
"""
import argparse
import sys


def main(argv=None) -> int:
    from repro.configs import ARCH_IDS
    from repro.configs.shapes import SHAPES
    from repro.launch.dryrun_lib import DryrunVariant, dryrun_one

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=list(ARCH_IDS), action="append")
    ap.add_argument("--shape", choices=list(SHAPES), action="append")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="every arch x shape on the selected mesh(es)")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--mixing", default="dense",
                    choices=("dense", "ppermute"))
    ap.add_argument("--topology", default="ring")
    ap.add_argument("--client-axis", default="")
    ap.add_argument("--fsdp-axis", default="")
    ap.add_argument("--dfl-m", type=int, default=0)
    ap.add_argument("--dfl-k", type=int, default=0)
    ap.add_argument("--microbatches", type=int, default=0)
    ap.add_argument("--loss-chunk", type=int, default=-1)
    ap.add_argument("--remat", action="store_true", default=None)
    ap.add_argument("--flash-decode", action="store_true")
    ap.add_argument("--kv-shard", default="", choices=("", "hd", "heads", "seq"))
    ap.add_argument("--metrics", default="full", choices=("full", "light"))
    ap.add_argument("--no-save", action="store_true")
    args = ap.parse_args(argv)

    archs = args.arch or (list(ARCH_IDS) if args.all else [])
    shapes = args.shape or (list(SHAPES) if args.all else [])
    if not archs or not shapes:
        ap.error("pass --arch/--shape or --all")
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    variant = DryrunVariant(
        name=args.variant, mixing=args.mixing, topology=args.topology,
        client_axis=args.client_axis, fsdp_axis=args.fsdp_axis,
        dfl_m=args.dfl_m, dfl_k=args.dfl_k, microbatches=args.microbatches,
        loss_chunk=args.loss_chunk, remat=args.remat,
        flash_decode=args.flash_decode, kv_shard=args.kv_shard,
        metrics=args.metrics)

    failures = 0
    for multi_pod in meshes:
        for arch in archs:
            for shape in shapes:
                try:
                    dryrun_one(arch, shape, multi_pod=multi_pod,
                               variant=variant, save=not args.no_save)
                except Exception as e:  # noqa: BLE001 - report and continue
                    failures += 1
                    print(f"[dryrun] FAIL {arch} x {shape} "
                          f"(multi_pod={multi_pod}): {type(e).__name__}: {e}",
                          file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
