"""Batched serving driver: prefill a prompt batch, then greedy-decode.

On CPU use ``--smoke``; the same step functions are what the dry-run
lowers at production size with the sharding rules applied.

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch zamba2-1.2b --smoke \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def main(argv=None) -> int:
    from repro.configs import ARCH_IDS, get_model_config, get_smoke_config
    from repro.data.synthetic import make_model_batch
    from repro.models import build_model

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="llama3-8b", choices=list(ARCH_IDS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else \
        get_model_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    max_seq = args.prompt_len + args.gen + 8

    batch = jax.tree.map(jnp.asarray,
                         make_model_batch(cfg, args.batch, args.prompt_len,
                                          seed=args.seed))
    batch.pop("labels", None)

    prefill = jax.jit(lambda p, b: model.prefill(p, b, max_seq))
    decode = jax.jit(model.decode_step)

    t0 = time.time()
    logits, cache = jax.block_until_ready(prefill(params, batch))
    t_prefill = time.time() - t0
    tok = jnp.argmax(logits, axis=-1)

    outs = [np.asarray(tok)]
    t0 = time.time()
    for _ in range(args.gen - 1):
        if cfg.arch_type == "audio":
            # audio decode consumes a frame embedding; feed the token's
            # one-hot projection as a stand-in frame
            step_in = jnp.zeros((args.batch, 1, cfg.d_model), jnp.float32)
        else:
            step_in = tok
        logits, cache = decode(params, cache, step_in)
        tok = jnp.argmax(logits, axis=-1)
        outs.append(np.asarray(tok))
    jax.block_until_ready(logits)
    t_decode = time.time() - t0

    gen = np.stack(outs, axis=1)
    tok_s = args.batch * (args.gen - 1) / max(t_decode, 1e-9)
    print(f"[serve] arch={cfg.name} batch={args.batch} "
          f"prefill({args.prompt_len} tok) {t_prefill*1e3:.1f}ms  "
          f"decode {args.gen-1} steps {t_decode*1e3:.1f}ms "
          f"({tok_s:.1f} tok/s)")
    print(f"[serve] sample continuation (seq 0): {gen[0][:12].tolist()}")
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
