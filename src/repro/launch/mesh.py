"""Production mesh factories.

Defined as FUNCTIONS so importing this module never touches jax device
state (jax locks the device count on first backend init).
"""
from __future__ import annotations

import jax

# TPU v5e hardware constants used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link


def _mesh(shape, axes):
    from jax.sharding import AxisType
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_debug_mesh(*, multi_pod: bool = False):
    """Small mesh for CI subprocess tests (needs >=8 host devices)."""
    shape = (2, 2, 2) if multi_pod else (2, 4)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def mesh_devices(mesh) -> int:
    import numpy as np
    return int(np.prod(mesh.devices.shape))
