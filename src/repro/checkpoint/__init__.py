from repro.checkpoint.checkpoint import (latest_step, restore_pytree,
                                         save_pytree)
