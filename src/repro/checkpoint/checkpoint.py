"""Pytree checkpointing: flat .npz payload + JSON treedef manifest.

No orbax offline; this is deliberately simple but complete — atomic
writes, step directories, dtype/shape validation on restore, and a
``latest_step`` scanner for resumption.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any

import jax
import numpy as np

PyTree = Any

_MANIFEST = "manifest.json"
_PAYLOAD = "arrays.npz"


def _flatten_with_paths(tree: PyTree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = np.asarray(leaf)
    return out


def save_pytree(directory: str, step: int, tree: PyTree) -> str:
    """Write ``directory/step_<step>/`` atomically; returns the path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    arrays = _flatten_with_paths(tree)
    manifest = {
        "step": step,
        "keys": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                 for k, v in arrays.items()},
    }
    tmp = tempfile.mkdtemp(dir=directory, prefix=".tmp_ckpt_")
    try:
        # npz cannot store ml_dtypes (bf16/fp8): widen on disk; the true
        # dtype lives in the manifest and is restored on load.
        savable = {k: (v.astype(np.float32) if v.dtype.kind == "V"
                       or str(v.dtype).startswith(("bfloat16", "float8"))
                       else v)
                   for k, v in arrays.items()}
        np.savez(os.path.join(tmp, _PAYLOAD), **savable)
        with open(os.path.join(tmp, _MANIFEST), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return final


def restore_pytree(directory: str, step: int, like: PyTree) -> PyTree:
    """Restore into the structure of ``like`` (validates shapes/dtypes)."""
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, _MANIFEST)) as f:
        manifest = json.load(f)
    with np.load(os.path.join(path, _PAYLOAD)) as payload:
        arrays = {k: payload[k] for k in payload.files}

    expect = _flatten_with_paths(like)
    if set(expect) != set(arrays):
        missing = set(expect) ^ set(arrays)
        raise ValueError(f"checkpoint key mismatch: {sorted(missing)[:5]} ...")
    for k, v in expect.items():
        got = manifest["keys"][k]
        if list(v.shape) != got["shape"]:
            raise ValueError(f"{k}: shape {got['shape']} != {list(v.shape)}")

    leaves_order = [
        "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        for path, _ in jax.tree_util.tree_flatten_with_path(like)[0]]
    treedef = jax.tree_util.tree_structure(like)
    import jax.numpy as jnp
    new_leaves = [jnp.asarray(arrays[k]).astype(expect[k].dtype)
                  for k in leaves_order]
    return jax.tree_util.tree_unflatten(treedef, new_leaves)


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_")]
    return max(steps) if steps else None
