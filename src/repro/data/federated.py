"""Federated data partitioning — the Dirichlet non-IID scheme of
Hsu, Qi & Brown (2019) used by the paper (Dir(0.3) / Dir(0.6) / IID).
"""
from __future__ import annotations

import numpy as np


def dirichlet_partition(labels: np.ndarray, m: int, alpha: float,
                        seed: int = 0, min_size: int = 2) -> list[np.ndarray]:
    """Partition sample indices across ``m`` clients with label ratios
    drawn from Dir(alpha).  Smaller alpha -> more heterogeneous."""
    rng = np.random.default_rng(seed)
    n_classes = int(labels.max()) + 1
    while True:
        client_idx: list[list[int]] = [[] for _ in range(m)]
        for c in range(n_classes):
            idx_c = np.flatnonzero(labels == c)
            rng.shuffle(idx_c)
            props = rng.dirichlet(np.full(m, alpha))
            cuts = (np.cumsum(props)[:-1] * len(idx_c)).astype(int)
            for cid, part in enumerate(np.split(idx_c, cuts)):
                client_idx[cid].extend(part.tolist())
        sizes = [len(ix) for ix in client_idx]
        if min(sizes) >= min_size:
            break
        seed += 1
        rng = np.random.default_rng(seed)
    return [np.asarray(sorted(ix), dtype=np.int64) for ix in client_idx]


def iid_partition(n: int, m: int, seed: int = 0) -> list[np.ndarray]:
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    return [np.sort(p) for p in np.array_split(perm, m)]


def partition_stats(labels: np.ndarray, parts: list[np.ndarray]) -> dict:
    n_classes = int(labels.max()) + 1
    counts = np.stack([np.bincount(labels[p], minlength=n_classes)
                       for p in parts])
    props = counts / np.maximum(counts.sum(axis=1, keepdims=True), 1)
    return {
        "sizes": counts.sum(axis=1),
        "class_props": props,
        # mean total-variation distance from the global label distribution
        "heterogeneity": float(np.mean(np.abs(
            props - labels_dist(labels)).sum(axis=1) / 2)),
    }


def labels_dist(labels: np.ndarray) -> np.ndarray:
    n_classes = int(labels.max()) + 1
    c = np.bincount(labels, minlength=n_classes).astype(np.float64)
    return c / c.sum()
