from repro.data.federated import (dirichlet_partition, iid_partition,
                                  partition_stats)
from repro.data.synthetic import (SyntheticClassification, SyntheticLM,
                                  make_dfl_lm_sampler, make_model_batch)
