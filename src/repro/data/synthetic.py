"""Synthetic data generators.

MNIST/CIFAR are not available offline; the faithful-reproduction
experiments use a controlled mixture-of-Gaussians classification task
(heterogeneity injected via Dirichlet label partitioning, exactly the
paper's scheme) plus a synthetic LM stream for the assigned archs.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.configs.base import ModelConfig
from repro.data.federated import dirichlet_partition, iid_partition


@dataclasses.dataclass
class SyntheticClassification:
    """Mixture-of-Gaussians classification with controllable difficulty."""
    n_classes: int = 10
    dim: int = 32
    n_train: int = 20000
    n_test: int = 4000
    noise: float = 0.9
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self.centers = rng.normal(size=(self.n_classes, self.dim)).astype(
            np.float32)
        self.x_train, self.y_train = self._draw(rng, self.n_train)
        self.x_test, self.y_test = self._draw(rng, self.n_test)

    def _draw(self, rng, n):
        y = rng.integers(0, self.n_classes, size=n)
        x = self.centers[y] + self.noise * rng.normal(
            size=(n, self.dim)).astype(np.float32)
        return x.astype(np.float32), y.astype(np.int32)

    def partition(self, m: int, alpha: float | None, seed: int = 0):
        """alpha=None -> IID; else Dirichlet(alpha)."""
        if alpha is None:
            return iid_partition(self.n_train, m, seed)
        return dirichlet_partition(self.y_train, m, alpha, seed)

    def client_sampler(self, parts, batch: int, K: int, seed: int = 0):
        """Returns sample_batches(t) -> (x (m,K,b,dim), y (m,K,b))."""
        m = len(parts)

        def sample(t):
            rng = np.random.default_rng((seed, t))
            xs = np.empty((m, K, batch, self.dim), np.float32)
            ys = np.empty((m, K, batch), np.int32)
            for i, idx in enumerate(parts):
                pick = rng.choice(idx, size=(K, batch), replace=True)
                xs[i] = self.x_train[pick]
                ys[i] = self.y_train[pick]
            return {"x": xs, "y": ys}

        return sample


@dataclasses.dataclass
class SyntheticLM:
    """Markov-chain token stream: learnable structure, per-client
    heterogeneity via distinct transition temperatures."""
    vocab: int = 512
    order_dim: int = 32
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        e = rng.normal(size=(self.vocab, self.order_dim))
        logits = e @ e.T / np.sqrt(self.order_dim)
        self.base_logits = logits.astype(np.float64)

    def sample_tokens(self, n_seq: int, seq_len: int, temp: float = 1.0,
                      seed: int = 0) -> np.ndarray:
        rng = np.random.default_rng(seed)
        p = np.exp(self.base_logits / temp)
        p /= p.sum(axis=1, keepdims=True)
        cdf = np.cumsum(p, axis=1)
        out = np.empty((n_seq, seq_len), np.int32)
        state = rng.integers(0, self.vocab, size=n_seq)
        for t in range(seq_len):
            out[:, t] = state
            u = rng.random(n_seq)
            state = np.array([np.searchsorted(cdf[s], x)
                              for s, x in zip(state, u)], dtype=np.int64)
            state = np.clip(state, 0, self.vocab - 1)
        return out


def make_model_batch(cfg: ModelConfig, batch: int, seq: int, seed: int = 0,
                     lead: tuple = ()) -> dict:
    """Random (structureless) batch with the exact input layout of
    ``configs.shapes`` — for smoke tests and micro-benchmarks."""
    rng = np.random.default_rng(seed)
    out: dict = {}
    if cfg.arch_type == "audio":
        out["embeds"] = rng.normal(size=lead + (batch, seq, cfg.d_model)
                                   ).astype(np.float32) * 0.02
        out["labels"] = rng.integers(0, cfg.vocab_size,
                                     lead + (batch, seq)).astype(np.int32)
        return out
    ntok = seq - cfg.prefix_tokens
    toks = rng.integers(0, cfg.vocab_size, lead + (batch, ntok)).astype(np.int32)
    out["tokens"] = toks
    out["labels"] = toks.copy()
    if cfg.arch_type == "vlm":
        out["embeds"] = rng.normal(
            size=lead + (batch, cfg.prefix_tokens, cfg.d_model)
        ).astype(np.float32) * 0.02
    return out


def make_dfl_lm_sampler(cfg: ModelConfig, m: int, K: int, batch: int,
                        seq: int, vocab_temps: np.ndarray | None = None,
                        seed: int = 0):
    """Heterogeneous per-client LM streams (client i uses temperature
    temps[i]); returns sample_batches(t) for core.dfl.simulate."""
    lm = SyntheticLM(vocab=cfg.vocab_size, seed=seed)
    temps = (vocab_temps if vocab_temps is not None
             else np.linspace(0.5, 2.0, m))

    def sample(t):
        toks = np.stack([
            lm.sample_tokens(K * batch, seq + 1, temp=float(temps[i]),
                             seed=(seed, t, i).__hash__() & 0x7fffffff)
            for i in range(m)]).reshape(m, K, batch, seq + 1)
        return {"tokens": toks[..., :-1].astype(np.int32),
                "labels": toks[..., 1:].astype(np.int32)}

    return sample
